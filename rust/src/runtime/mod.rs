//! Runtime: load AOT HLO-text artifacts and execute them from the
//! coordinator's hot path, through one of two backends behind the
//! [`ExecBackend`] seam:
//!
//! * **PJRT** (preferred): HLO text -> HloModuleProto -> XlaComputation ->
//!   compile -> execute, following /opt/xla-example/load_hlo. All graphs
//!   are lowered with return_tuple=True, so outputs arrive as one tuple
//!   literal that we unpack into tensors.
//! * **Interpreter** (fallback): when `PjRtClient::compile` fails — e.g.
//!   the offline `vendor/xla-stub` build — the artifact's HLO text is
//!   parsed and evaluated by the in-repo interpreter (`crate::hlo`).
//!   Same inputs, same outputs, so every caller works unchanged and
//!   artifacts execute in any container.
//!
//! The runtime is `Sync`: the executable cache and stats sit behind
//! mutexes so the sweep engine's workers share one set of compiled (or
//! parsed) artifacts instead of recompiling per configuration. On top of
//! that, [`Runtime::run_batch`] executes one artifact over many
//! independent input sets concurrently on a `util::pool::Pool` — the
//! batch-parallel seam behind calibrate and eval (DESIGN.md §9).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::hlo;
use crate::model::manifest::{ArtifactSig, Manifest, TensorSig};
use crate::tensor::{IntTensor, Tensor};
use crate::util::pool::Pool;

/// A typed input value for an executable.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("value is not f32"),
        }
    }

    fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal> {
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => {
                if t.len() != sig.shape.iter().product::<usize>() {
                    bail!(
                        "input {}: have {} elems, signature wants {:?}",
                        sig.name,
                        t.len(),
                        sig.shape
                    );
                }
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            Value::I32(t) => {
                if t.data().len() != sig.shape.iter().product::<usize>() {
                    bail!(
                        "input {}: have {} elems, signature wants {:?}",
                        sig.name,
                        t.data().len(),
                        sig.shape
                    );
                }
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Value {
        Value::I32(t)
    }
}

/// Execution statistics for the perf pass.
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    /// executions served by the HLO interpreter (vs a PJRT executable)
    pub interpreted: u64,
    /// executions dispatched by the serving layer (`serve::queue`), a
    /// subset of `executions` — distinguishes online traffic from batch
    /// jobs in one shared runtime
    pub served: u64,
    pub exec_nanos: u64,
    pub input_prep_nanos: u64,
    pub output_fetch_nanos: u64,
    /// serve-layer model-cache counters (`serve::cache` folds its deltas
    /// in via [`Runtime::note_model_cache`])
    pub model_cache_hits: u64,
    pub model_cache_misses: u64,
    pub model_cache_evictions: u64,
}

/// How an artifact executes: a compiled PJRT executable, or the parsed
/// HLO module evaluated by the in-repo interpreter. Both are `Sync`, so
/// the cache is shared across sweep workers either way.
pub enum ExecBackend {
    Pjrt(xla::PjRtLoadedExecutable),
    Interp {
        module: hlo::HloModule,
        /// execution plan built once when the artifact is cached
        /// (`hlo::plan`); `None` means planning failed and the naive
        /// engine serves this artifact (the loud safety valve)
        plan: Option<hlo::Plan>,
    },
}

/// A cached, executable artifact.
pub struct Executable {
    name: String,
    backend: ExecBackend,
}

impl Executable {
    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            ExecBackend::Pjrt(_) => "pjrt",
            ExecBackend::Interp { .. } => "interpreter",
        }
    }
}

/// The runtime: a PJRT CPU client plus an executable cache keyed by
/// artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: Mutex<BTreeMap<String, Arc<Executable>>>,
    stats: Mutex<RuntimeStats>,
    /// Force the naive (per-instruction) interpreter even when a plan is
    /// available. Settable via `TQ_INTERP=naive` or
    /// [`Runtime::set_naive_interp`]; exists so the bench harness can
    /// measure the pre-plan baseline in-tree and as an escape hatch if a
    /// planned execution ever misbehaves in the field.
    naive_interp: AtomicBool,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (with manifest.json).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir: PathBuf = artifacts_dir.into();
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let naive = std::env::var("TQ_INTERP").as_deref() == Ok("naive");
        Ok(Runtime {
            client,
            manifest,
            executables: Mutex::new(BTreeMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
            naive_interp: AtomicBool::new(naive),
        })
    }

    /// Force (or release) the naive per-instruction interpreter. The
    /// bench harness uses this to time the pre-plan baseline in the same
    /// process; `TQ_INTERP=naive` sets it at construction.
    pub fn set_naive_interp(&self, naive: bool) {
        self.naive_interp.store(naive, Ordering::Relaxed);
    }

    fn use_naive_interp(&self) -> bool {
        self.naive_interp.load(Ordering::Relaxed)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().expect("runtime stats").clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.lock().expect("runtime stats") = RuntimeStats::default();
    }

    /// Fold serve-layer model-cache counter deltas into the shared stats,
    /// under the same lock `stats`/`reset_stats` take — so a snapshot
    /// never observes a half-applied delta.
    pub fn note_model_cache(&self, hits: u64, misses: u64, evictions: u64) {
        let mut st = self.stats.lock().expect("runtime stats");
        st.model_cache_hits += hits;
        st.model_cache_misses += misses;
        st.model_cache_evictions += evictions;
    }

    /// Compile (or fetch from cache) an artifact's executable. When PJRT
    /// compilation fails (e.g. the offline `vendor/xla-stub` build), the
    /// artifact's HLO text is parsed for the interpreter backend instead.
    /// The cache is shared across threads; compilation happens outside the
    /// lock so concurrent sweep workers never serialise on a slow compile
    /// (a lost race costs one redundant compile, and the first insert
    /// wins).
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.executables.lock().expect("executable cache").get(name) {
            return Ok(e.clone());
        }
        let sig = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(&sig.file)
            .map_err(|e| anyhow!("parsing {}: {e:?}", sig.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let backend = match self.client.compile(&comp) {
            Ok(exe) => ExecBackend::Pjrt(exe),
            Err(pjrt_err) => {
                let text = std::fs::read_to_string(&sig.file)
                    .with_context(|| format!("reading {}", sig.file.display()))?;
                let module = hlo::parse_module(&text).map_err(|parse_err| {
                    anyhow!(
                        "compiling {name}: PJRT failed ({pjrt_err:?}) and the \
                         interpreter fallback could not parse the module: {parse_err}"
                    )
                })?;
                // Cache admission gate: a module that does not pass static
                // shape/dtype verification never reaches interp or plan
                // (which is what lets their per-execution shape checks
                // retreat behind debug_assertions).
                hlo::verify(&module)
                    .with_context(|| format!("verifying {name} for the interpreter fallback"))?;
                // Once per artifact (results are cached): the fallback must
                // be observable — it changes both throughput and f32
                // accumulation order vs a compiled executable, and a
                // genuine compile failure of a real PJRT binding must not
                // vanish silently.
                eprintln!(
                    "[runtime] {name}: PJRT compile failed ({pjrt_err}); \
                     falling back to the in-repo HLO interpreter"
                );
                // Plan once here so every execution amortises the pass.
                // Planning is total for modules the naive engine can run,
                // so a failure is loud (and leaves the artifact on the
                // naive engine rather than unusable).
                let plan = match hlo::Plan::build(&module) {
                    Ok(p) => Some(p),
                    Err(plan_err) => {
                        eprintln!(
                            "[runtime] {name}: execution planning failed \
                             ({plan_err:#}); staying on the naive interpreter"
                        );
                        None
                    }
                };
                ExecBackend::Interp { module, plan }
            }
        };
        let exe = Executable { name: name.to_string(), backend };
        let mut cache = self.executables.lock().expect("executable cache");
        let entry = cache.entry(name.to_string()).or_insert_with(|| Arc::new(exe));
        Ok(entry.clone())
    }

    /// Execute an artifact with inputs in signature order.
    /// Returns the output tensors in signature order (i32 outputs are not
    /// used by any of our graphs, so everything comes back as f32).
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let sig = self.manifest.artifact(name)?.clone();
        check_input_count(&sig, name, inputs.len())?;
        let exe = self.executable(name)?;
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&sig.inputs)
            .map(|(v, s)| v.to_literal(s))
            .collect::<Result<_>>()?;
        self.stats.lock().expect("runtime stats").input_prep_nanos +=
            t0.elapsed().as_nanos() as u64;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.execute_artifact(&sig, &exe, &refs)
    }

    /// Low-level execute: caller builds the literal list (in signature
    /// order) directly — avoids cloning large tensors into `Value`s on the
    /// training hot loop. Count is validated against the signature; shapes
    /// are the caller's responsibility (the backend still rejects
    /// mismatches).
    pub fn run_lits(&self, name: &str, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let sig = self.manifest.artifact(name)?.clone();
        check_input_count(&sig, name, literals.len())?;
        let exe = self.executable(name)?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.execute_artifact(&sig, &exe, &refs)
    }

    /// Like [`Runtime::run_lits`], but over borrowed literals — lets
    /// callers keep a cache of static inputs (params, quant policy) across
    /// many calls and only rebuild the per-batch literals.
    pub fn run_lits_borrowed(&self, name: &str, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let sig = self.manifest.artifact(name)?.clone();
        check_input_count(&sig, name, literals.len())?;
        let exe = self.executable(name)?;
        self.execute_artifact(&sig, &exe, literals)
    }

    /// Execute one artifact over `n_items` independent input sets
    /// concurrently on `pool` — the batch-parallel hot loop behind
    /// calibrate and eval. `statics` are the inputs shared by every item
    /// (parameter tensors, quantizer tensors) in signature order;
    /// `prep(i)` builds item `i`'s trailing per-call literals *on the
    /// worker that executes it*, so input-literal prep overlaps other
    /// items' execution.
    ///
    /// Results come back indexed by item (the first error in item order
    /// wins), and each item's execution math is identical to a lone
    /// `run_lits_borrowed` call, so a caller that consumes the vector in
    /// order is bit-identical to the serial loop it replaces — the
    /// contract tests/determinism.rs pins across `TQ_THREADS` settings.
    ///
    /// On the interpreter backend the static literals are converted to
    /// interpreter values once per *call* instead of once per
    /// *execution*, and the preplanned engine's `Cow`-style env borrows
    /// them per item (`Slot::Ref`), so the shared parameter tensors are
    /// zero-copy on the per-item path. Per-item timing is aggregated
    /// locally and merged into the shared stats under one lock per call.
    pub fn run_batch<F>(
        &self,
        name: &str,
        statics: &[xla::Literal],
        n_items: usize,
        prep: F,
        pool: &Pool,
    ) -> Result<Vec<Vec<Tensor>>>
    where
        F: Fn(usize) -> Result<Vec<xla::Literal>> + Sync,
    {
        self.run_batch_inner(name, statics, n_items, prep, pool, false)
    }

    /// [`Runtime::run_batch`] for the serving layer: identical execution
    /// and results, but every item is also attributed to the `served`
    /// stats counter so online traffic is distinguishable from batch
    /// jobs sharing this runtime.
    pub fn run_batch_served<F>(
        &self,
        name: &str,
        statics: &[xla::Literal],
        n_items: usize,
        prep: F,
        pool: &Pool,
    ) -> Result<Vec<Vec<Tensor>>>
    where
        F: Fn(usize) -> Result<Vec<xla::Literal>> + Sync,
    {
        self.run_batch_inner(name, statics, n_items, prep, pool, true)
    }

    fn run_batch_inner<F>(
        &self,
        name: &str,
        statics: &[xla::Literal],
        n_items: usize,
        prep: F,
        pool: &Pool,
        served: bool,
    ) -> Result<Vec<Vec<Tensor>>>
    where
        F: Fn(usize) -> Result<Vec<xla::Literal>> + Sync,
    {
        let sig = self.manifest.artifact(name)?.clone();
        // resolve (and, cold, compile/parse) once before fanning out so
        // items never race on the executable cache within one call
        let exe = self.executable(name)?;
        match &exe.backend {
            ExecBackend::Pjrt(_) => {
                let sig = &sig;
                let exe = &exe;
                let prep = &prep;
                let jobs: Vec<_> = (0..n_items)
                    .map(|i| {
                        move || -> Result<(Vec<Tensor>, [u64; 3], bool)> {
                            let t0 = Instant::now();
                            let per = prep(i)?;
                            check_input_count(sig, &sig.name, statics.len() + per.len())?;
                            let prep_ns = t0.elapsed().as_nanos() as u64;
                            let refs: Vec<&xla::Literal> =
                                statics.iter().chain(per.iter()).collect();
                            let (out, [exec_ns, fetch_ns], interpreted) =
                                self.execute_artifact_timed(sig, exe, &refs)?;
                            Ok((out, [prep_ns, exec_ns, fetch_ns], interpreted))
                        }
                    })
                    .collect();
                let results = pool.run(jobs);
                self.merge_batch_stats(results, served, 0)
            }
            ExecBackend::Interp { module, plan } => {
                let shapes = module.entry_param_shapes();
                if shapes.len() != sig.inputs.len() {
                    bail!(
                        "artifact {name}: module wants {} parameters, signature has {}",
                        shapes.len(),
                        sig.inputs.len()
                    );
                }
                if statics.len() > shapes.len() {
                    bail!(
                        "artifact {name}: {} static inputs exceed the {} parameters",
                        statics.len(),
                        shapes.len()
                    );
                }
                let t0 = Instant::now();
                let static_vals: Vec<hlo::Value> = statics
                    .iter()
                    .zip(shapes.iter().copied())
                    .enumerate()
                    .map(|(i, (lit, shape))| literal_to_value(lit, shape, i))
                    .collect::<Result<_>>()
                    .with_context(|| format!("preparing {name} static inputs"))?;
                let statics_prep_nanos = t0.elapsed().as_nanos() as u64;
                let per_shapes = &shapes[statics.len()..];
                let sig = &sig;
                let static_vals = &static_vals;
                let prep = &prep;
                // The planned engine borrows the shared statics per item
                // (Cow env: `Slot::Ref`), so each execution is zero-copy
                // over the parameter tensors; the naive engine still
                // clones them into its env.
                let use_plan: Option<&hlo::Plan> =
                    if self.use_naive_interp() { None } else { plan.as_ref() };
                // Per-item timing rides back with each result so the
                // shared stats mutex is taken once per call, not three
                // times per item at eval rates.
                let jobs: Vec<_> = (0..n_items)
                    .map(|i| {
                        move || -> Result<(Vec<Tensor>, [u64; 3], bool)> {
                            let t0 = Instant::now();
                            let per_lits = prep(i)?;
                            check_input_count(
                                sig,
                                &sig.name,
                                statics.len() + per_lits.len(),
                            )?;
                            let per_vals: Vec<hlo::Value> = per_lits
                                .iter()
                                .zip(per_shapes.iter().copied())
                                .enumerate()
                                .map(|(j, (lit, shape))| {
                                    literal_to_value(lit, shape, statics.len() + j)
                                })
                                .collect::<Result<_>>()
                                .with_context(|| {
                                    format!("preparing {} item {i} inputs", sig.name)
                                })?;
                            let t1 = Instant::now();
                            let refs: Vec<&hlo::Value> =
                                static_vals.iter().chain(per_vals.iter()).collect();
                            let outs = match use_plan {
                                Some(p) => p.execute(&refs).with_context(|| {
                                    format!("interpreting {} item {i} (planned)", sig.name)
                                })?,
                                None => {
                                    hlo::interpret_refs(module, &refs).with_context(|| {
                                        format!("interpreting {} item {i}", sig.name)
                                    })?
                                }
                            };
                            let t2 = Instant::now();
                            let out = parts_to_tensors(sig, PartsBuf::Values(outs))?;
                            let t3 = Instant::now();
                            let nanos = [
                                (t1 - t0).as_nanos() as u64,
                                (t2 - t1).as_nanos() as u64,
                                (t3 - t2).as_nanos() as u64,
                            ];
                            Ok((out, nanos, true))
                        }
                    })
                    .collect();
                let results = pool.run(jobs);
                self.merge_batch_stats(results, served, statics_prep_nanos)
            }
        }
    }

    /// Merge a batch call's per-item results into the shared stats under
    /// ONE lock acquisition, so `stats`/`reset_stats` snapshots never
    /// interleave with a half-accounted batch.
    fn merge_batch_stats(
        &self,
        results: Vec<Result<(Vec<Tensor>, [u64; 3], bool)>>,
        served: bool,
        statics_prep_nanos: u64,
    ) -> Result<Vec<Vec<Tensor>>> {
        let mut st = self.stats.lock().expect("runtime stats");
        st.input_prep_nanos += statics_prep_nanos;
        let mut out = Vec::with_capacity(results.len());
        for r in results {
            match r {
                Ok((tensors, [prep_ns, exec_ns, fetch_ns], interpreted)) => {
                    st.executions += 1;
                    if interpreted {
                        st.interpreted += 1;
                    }
                    if served {
                        st.served += 1;
                    }
                    st.input_prep_nanos += prep_ns;
                    st.exec_nanos += exec_ns;
                    st.output_fetch_nanos += fetch_ns;
                    out.push(Ok(tensors));
                }
                Err(e) => out.push(Err(e)),
            }
        }
        drop(st);
        out.into_iter().collect()
    }

    /// The one post-execute path shared by [`Runtime::run`],
    /// [`Runtime::run_lits`] and [`Runtime::run_lits_borrowed`]: dispatch
    /// to the backend, unpack the output tuple, convert to tensors,
    /// account stats. An empty PJRT execute result is an error here, not
    /// a panic.
    fn execute_artifact(
        &self,
        sig: &ArtifactSig,
        exe: &Executable,
        literals: &[&xla::Literal],
    ) -> Result<Vec<Tensor>> {
        let (out, [exec_ns, fetch_ns], interpreted) =
            self.execute_artifact_timed(sig, exe, literals)?;
        let mut st = self.stats.lock().expect("runtime stats");
        st.executions += 1;
        if interpreted {
            st.interpreted += 1;
        }
        st.exec_nanos += exec_ns;
        st.output_fetch_nanos += fetch_ns;
        Ok(out)
    }

    /// [`Runtime::execute_artifact`] minus the accounting: returns the
    /// output tensors plus `[exec, fetch]` nanos and whether the
    /// interpreter served the call, without touching the stats mutex —
    /// batch callers aggregate per-item timings and merge them under one
    /// lock per call.
    fn execute_artifact_timed(
        &self,
        sig: &ArtifactSig,
        exe: &Executable,
        literals: &[&xla::Literal],
    ) -> Result<(Vec<Tensor>, [u64; 2], bool)> {
        let name = exe.name.as_str();
        let t1 = Instant::now();
        let (parts, interpreted) = match &exe.backend {
            ExecBackend::Pjrt(p) => {
                let result = p
                    .execute::<&xla::Literal>(literals)
                    .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
                let buf = result
                    .first()
                    .and_then(|device| device.first())
                    .ok_or_else(|| anyhow!("executing {name}: empty execute result"))?;
                let tuple = buf
                    .to_literal_sync()
                    .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
                let parts =
                    tuple.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
                (PartsBuf::Literals(parts), false)
            }
            ExecBackend::Interp { module, plan } => {
                // Inputs convert (one copy) per call, even for literals a
                // caller caches across calls — a few hundred KB of memcpy
                // vs tens of ms of interpreted matmuls per forward, so a
                // pointer-keyed conversion cache is not worth its
                // complexity until profiles say otherwise.
                let inputs = literals_to_values(module, literals)
                    .with_context(|| format!("preparing {name} interpreter inputs"))?;
                let outs = match plan {
                    Some(p) if !self.use_naive_interp() => {
                        let refs: Vec<&hlo::Value> = inputs.iter().collect();
                        p.execute(&refs)
                            .with_context(|| format!("interpreting {name} (planned)"))?
                    }
                    _ => hlo::interpret(module, &inputs)
                        .with_context(|| format!("interpreting {name}"))?,
                };
                (PartsBuf::Values(outs), true)
            }
        };
        let t2 = Instant::now();
        let out = parts_to_tensors(sig, parts)?;
        let t3 = Instant::now();
        let nanos = [(t2 - t1).as_nanos() as u64, (t3 - t2).as_nanos() as u64];
        Ok((out, nanos, interpreted))
    }
}

fn check_input_count(sig: &ArtifactSig, name: &str, given: usize) -> Result<()> {
    if given != sig.inputs.len() {
        bail!(
            "artifact {name}: {given} inputs given, signature wants {}",
            sig.inputs.len()
        );
    }
    Ok(())
}

/// Output buffer of either backend, unified before tensor conversion.
enum PartsBuf {
    Literals(Vec<xla::Literal>),
    Values(Vec<hlo::Value>),
}

fn parts_to_tensors(sig: &ArtifactSig, parts: PartsBuf) -> Result<Vec<Tensor>> {
    let n = match &parts {
        PartsBuf::Literals(v) => v.len(),
        PartsBuf::Values(v) => v.len(),
    };
    if n != sig.outputs.len() {
        bail!(
            "artifact {}: got {n} outputs, signature wants {}",
            sig.name,
            sig.outputs.len()
        );
    }
    match parts {
        PartsBuf::Literals(parts) => parts
            .into_iter()
            .zip(&sig.outputs)
            .map(|(lit, os)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output {}: {e:?}", os.name))?;
                Tensor::new(os.shape.clone(), data)
            })
            .collect(),
        PartsBuf::Values(parts) => parts
            .into_iter()
            .zip(&sig.outputs)
            .map(|(v, os)| {
                let data = match v {
                    hlo::Value::F32 { data, .. } => data,
                    other => bail!(
                        "output {}: interpreter produced {:?}, wanted f32",
                        os.name,
                        other.dtype()
                    ),
                };
                Tensor::new(os.shape.clone(), data)
            })
            .collect(),
    }
}

/// Convert caller literals into interpreter values, taking shapes from the
/// parsed module's own parameter declarations (the authoritative source).
fn literals_to_values(
    module: &hlo::HloModule,
    literals: &[&xla::Literal],
) -> Result<Vec<hlo::Value>> {
    let shapes = module.entry_param_shapes();
    if literals.len() != shapes.len() {
        bail!(
            "module wants {} parameters, got {} literals",
            shapes.len(),
            literals.len()
        );
    }
    literals
        .iter()
        .copied()
        .zip(shapes)
        .enumerate()
        .map(|(i, (lit, shape))| literal_to_value(lit, shape, i))
        .collect()
}

/// Convert one caller literal into the interpreter value for parameter
/// `i`, checked against the module's declared shape.
fn literal_to_value(lit: &xla::Literal, shape: &hlo::Shape, i: usize) -> Result<hlo::Value> {
    let dims = shape.dims()?.to_vec();
    let want: usize = dims.iter().product();
    if lit.element_count() != want {
        bail!(
            "parameter {i}: literal has {} elements (dims {:?}), module wants {dims:?}",
            lit.element_count(),
            lit.dims()
        );
    }
    match shape.dtype()? {
        hlo::DType::F32 => Ok(hlo::Value::F32 {
            dims,
            data: lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("parameter {i}: {e:?}"))?,
        }),
        hlo::DType::S32 => Ok(hlo::Value::S32 {
            dims,
            data: lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("parameter {i}: {e:?}"))?,
        }),
        hlo::DType::Pred => bail!("parameter {i}: pred inputs unsupported"),
    }
}

/// Literal constructors (shape checked against element count by the crate).
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_scalar(x: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&[x]).reshape(&[])?)
}

/// Convenience: build the flat Value list for a forward/diag artifact.
pub struct ForwardInputs<'a> {
    pub params: &'a crate::model::Params,
    pub act_scales: Vec<f32>,
    pub act_zps: Vec<f32>,
    pub act_cfg: Vec<f32>,
    pub ids: Vec<i32>,
    pub token_type: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub n_sites: usize,
}

impl<'a> ForwardInputs<'a> {
    pub fn to_values(&self) -> Result<Vec<Value>> {
        let mut vals: Vec<Value> = Vec::with_capacity(self.params.tensors.len() + 6);
        for t in &self.params.tensors {
            vals.push(Value::F32(t.clone()));
        }
        let s = self.act_scales.len();
        vals.push(Value::F32(Tensor::new(vec![s], self.act_scales.clone())?));
        vals.push(Value::F32(Tensor::new(vec![s], self.act_zps.clone())?));
        vals.push(Value::F32(Tensor::new(
            vec![self.n_sites, 3],
            self.act_cfg.clone(),
        )?));
        vals.push(Value::I32(IntTensor::new(
            vec![self.batch, self.seq],
            self.ids.clone(),
        )?));
        vals.push(Value::I32(IntTensor::new(
            vec![self.batch, self.seq],
            self.token_type.clone(),
        )?));
        vals.push(Value::F32(Tensor::new(
            vec![self.batch, self.seq],
            self.mask.clone(),
        )?));
        Ok(vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_validation() {
        let sig = TensorSig { name: "x".into(), shape: vec![2, 2], dtype: "f32".into() };
        let ok = Value::F32(Tensor::zeros(&[2, 2]));
        assert!(ok.to_literal(&sig).is_ok());
        let bad = Value::F32(Tensor::zeros(&[3]));
        assert!(bad.to_literal(&sig).is_err());
    }

    #[test]
    fn scalar_literal() {
        let sig = TensorSig { name: "lr".into(), shape: vec![], dtype: "f32".into() };
        let v = Value::F32(Tensor::scalar(0.5));
        let lit = v.to_literal(&sig).unwrap();
        assert_eq!(lit.element_count(), 1);
    }
}
