//! PJRT runtime: load AOT HLO-text artifacts, compile them once on the CPU
//! PJRT client, and execute them from the coordinator's hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* -> HloModuleProto
//! -> XlaComputation -> compile -> execute. All graphs are lowered with
//! return_tuple=True, so outputs arrive as one tuple literal that we
//! unpack into tensors.
//!
//! The runtime is `Sync`: the executable cache and stats sit behind
//! mutexes so the sweep engine's workers share one set of compiled
//! artifacts instead of recompiling per configuration (compilation is the
//! dominant cost for the QAT/eval graphs).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::manifest::{ArtifactSig, Manifest, TensorSig};
use crate::tensor::{IntTensor, Tensor};

/// A typed input value for an executable.
#[derive(Debug, Clone)]
pub enum Value {
    F32(Tensor),
    I32(IntTensor),
}

impl Value {
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            _ => bail!("value is not f32"),
        }
    }

    fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal> {
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            Value::F32(t) => {
                if t.len() != sig.shape.iter().product::<usize>() {
                    bail!(
                        "input {}: have {} elems, signature wants {:?}",
                        sig.name,
                        t.len(),
                        sig.shape
                    );
                }
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            Value::I32(t) => {
                if t.data().len() != sig.shape.iter().product::<usize>() {
                    bail!(
                        "input {}: have {} elems, signature wants {:?}",
                        sig.name,
                        t.data().len(),
                        sig.shape
                    );
                }
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Value {
        Value::F32(t)
    }
}

impl From<IntTensor> for Value {
    fn from(t: IntTensor) -> Value {
        Value::I32(t)
    }
}

/// Execution statistics for the perf pass.
#[derive(Debug, Clone, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_nanos: u64,
    pub input_prep_nanos: u64,
    pub output_fetch_nanos: u64,
}

/// The runtime: a PJRT CPU client plus an executable cache keyed by
/// artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: Mutex<BTreeMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (with manifest.json).
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let dir: PathBuf = artifacts_dir.into();
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            executables: Mutex::new(BTreeMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().expect("runtime stats").clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.lock().expect("runtime stats") = RuntimeStats::default();
    }

    /// Compile (or fetch from cache) an artifact's executable. The cache
    /// is shared across threads; compilation happens outside the lock so
    /// concurrent sweep workers never serialise on a slow compile (a lost
    /// race costs one redundant compile, and the first insert wins).
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.executables.lock().expect("executable cache").get(name) {
            return Ok(e.clone());
        }
        let sig = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(&sig.file)
            .map_err(|e| anyhow!("parsing {}: {e:?}", sig.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let mut cache = self.executables.lock().expect("executable cache");
        let entry = cache.entry(name.to_string()).or_insert_with(|| Arc::new(exe));
        Ok(entry.clone())
    }

    /// Execute an artifact with inputs in signature order.
    /// Returns the output tensors in signature order (i32 outputs are not
    /// used by any of our graphs, so everything comes back as f32).
    pub fn run(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let sig = self.manifest.artifact(name)?.clone();
        if inputs.len() != sig.inputs.len() {
            bail!(
                "artifact {name}: {} inputs given, signature wants {}",
                inputs.len(),
                sig.inputs.len()
            );
        }
        let exe = self.executable(name)?;

        let t0 = std::time::Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&sig.inputs)
            .map(|(v, s)| v.to_literal(s))
            .collect::<Result<_>>()?;
        let t1 = std::time::Instant::now();

        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        let t2 = std::time::Instant::now();

        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        let out = self.literals_to_tensors(&sig, parts)?;
        let t3 = std::time::Instant::now();

        let mut st = self.stats.lock().expect("runtime stats");
        st.executions += 1;
        st.input_prep_nanos += (t1 - t0).as_nanos() as u64;
        st.exec_nanos += (t2 - t1).as_nanos() as u64;
        st.output_fetch_nanos += (t3 - t2).as_nanos() as u64;
        Ok(out)
    }

    /// Low-level execute: caller builds the literal list (in signature
    /// order) directly — avoids cloning large tensors into `Value`s on the
    /// training hot loop. Count is validated against the signature; shapes
    /// are the caller's responsibility (XLA still rejects mismatches).
    pub fn run_lits(&self, name: &str, literals: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let sig = self.manifest.artifact(name)?.clone();
        if literals.len() != sig.inputs.len() {
            bail!(
                "artifact {name}: {} literals given, signature wants {}",
                literals.len(),
                sig.inputs.len()
            );
        }
        let exe = self.executable(name)?;
        let t1 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        let t2 = std::time::Instant::now();
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        let out = self.literals_to_tensors(&sig, parts)?;
        let t3 = std::time::Instant::now();
        let mut st = self.stats.lock().expect("runtime stats");
        st.executions += 1;
        st.exec_nanos += (t2 - t1).as_nanos() as u64;
        st.output_fetch_nanos += (t3 - t2).as_nanos() as u64;
        Ok(out)
    }

    /// Like [`run_lits`], but over borrowed literals — lets callers keep a
    /// cache of static inputs (params, quant policy) across many calls and
    /// only rebuild the per-batch literals.
    pub fn run_lits_borrowed(&self, name: &str, literals: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        let sig = self.manifest.artifact(name)?.clone();
        if literals.len() != sig.inputs.len() {
            bail!(
                "artifact {name}: {} literals given, signature wants {}",
                literals.len(),
                sig.inputs.len()
            );
        }
        let exe = self.executable(name)?;
        let t1 = std::time::Instant::now();
        let result = exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} output: {e:?}"))?;
        let t2 = std::time::Instant::now();
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
        let out = self.literals_to_tensors(&sig, parts)?;
        let t3 = std::time::Instant::now();
        let mut st = self.stats.lock().expect("runtime stats");
        st.executions += 1;
        st.exec_nanos += (t2 - t1).as_nanos() as u64;
        st.output_fetch_nanos += (t3 - t2).as_nanos() as u64;
        Ok(out)
    }

    fn literals_to_tensors(
        &self,
        sig: &ArtifactSig,
        parts: Vec<xla::Literal>,
    ) -> Result<Vec<Tensor>> {
        if parts.len() != sig.outputs.len() {
            bail!(
                "artifact {}: got {} outputs, signature wants {}",
                sig.name,
                parts.len(),
                sig.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&sig.outputs)
            .map(|(lit, os)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output {}: {e:?}", os.name))?;
                Tensor::new(os.shape.clone(), data)
            })
            .collect()
    }
}

/// Literal constructors (shape checked against element count by the crate).
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

pub fn lit_scalar(x: f32) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&[x]).reshape(&[])?)
}

/// Convenience: build the flat Value list for a forward/diag artifact.
pub struct ForwardInputs<'a> {
    pub params: &'a crate::model::Params,
    pub act_scales: Vec<f32>,
    pub act_zps: Vec<f32>,
    pub act_cfg: Vec<f32>,
    pub ids: Vec<i32>,
    pub token_type: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub n_sites: usize,
}

impl<'a> ForwardInputs<'a> {
    pub fn to_values(&self) -> Result<Vec<Value>> {
        let mut vals: Vec<Value> = Vec::with_capacity(self.params.tensors.len() + 6);
        for t in &self.params.tensors {
            vals.push(Value::F32(t.clone()));
        }
        let s = self.act_scales.len();
        vals.push(Value::F32(Tensor::new(vec![s], self.act_scales.clone())?));
        vals.push(Value::F32(Tensor::new(vec![s], self.act_zps.clone())?));
        vals.push(Value::F32(Tensor::new(
            vec![self.n_sites, 3],
            self.act_cfg.clone(),
        )?));
        vals.push(Value::I32(IntTensor::new(
            vec![self.batch, self.seq],
            self.ids.clone(),
        )?));
        vals.push(Value::I32(IntTensor::new(
            vec![self.batch, self.seq],
            self.token_type.clone(),
        )?));
        vals.push(Value::F32(Tensor::new(
            vec![self.batch, self.seq],
            self.mask.clone(),
        )?));
        Ok(vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_validation() {
        let sig = TensorSig { name: "x".into(), shape: vec![2, 2], dtype: "f32".into() };
        let ok = Value::F32(Tensor::zeros(&[2, 2]));
        assert!(ok.to_literal(&sig).is_ok());
        let bad = Value::F32(Tensor::zeros(&[3]));
        assert!(bad.to_literal(&sig).is_err());
    }

    #[test]
    fn scalar_literal() {
        let sig = TensorSig { name: "lr".into(), shape: vec![], dtype: "f32".into() };
        let v = Value::F32(Tensor::scalar(0.5));
        let lit = v.to_literal(&sig).unwrap();
        assert_eq!(lit.element_count(), 1);
    }
}
