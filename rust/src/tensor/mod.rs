//! Minimal dense tensor types (row-major f32 / i32).
//!
//! This is the substrate the coordinator computes on: calibration
//! statistics, quantization simulation, AdaRound reconstruction, metric
//! computation. It intentionally supports exactly what the pipeline needs —
//! shapes, lane-wise reductions over the last axis, matmul, and elementwise
//! maps — with contiguous storage that converts to/from PJRT literals
//! without copies of copies.

use anyhow::{bail, Result};

use crate::util::pool::Pool;
use crate::util::rng::Rng;

/// Below this element count the pooled reductions stay serial — thread
/// spawn costs more than the scan. (Thresholds never change results: the
/// parallel merges are exact.)
const PAR_MIN_ELEMS: usize = 1 << 15;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|i| f(i)).collect() }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        Tensor::from_fn(shape, |_| rng.normal_f32(0.0, std))
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of lanes in the last axis (1 for scalars).
    pub fn last_dim(&self) -> usize {
        self.shape.last().copied().unwrap_or(1)
    }

    /// Rows = product of all axes but the last.
    pub fn rows(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn std(&self) -> f32 {
        if self.data.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.data.iter().map(|&x| (x - m) * (x - m)).sum::<f32>()
            / self.data.len() as f32)
            .sqrt()
    }

    /// Per-lane (last-axis) min and max, reduced over all rows.
    pub fn lane_min_max(&self) -> (Vec<f32>, Vec<f32>) {
        let d = self.last_dim();
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for row in self.data.chunks_exact(d) {
            for (j, &x) in row.iter().enumerate() {
                if x < lo[j] {
                    lo[j] = x;
                }
                if x > hi[j] {
                    hi[j] = x;
                }
            }
        }
        if self.data.is_empty() {
            lo.fill(0.0);
            hi.fill(0.0);
        }
        (lo, hi)
    }

    /// Pool-parallel [`Tensor::lane_min_max`]: row blocks reduce on worker
    /// threads, block results merge with exact min/max — bit-identical to
    /// the serial scan for any worker count.
    pub fn lane_min_max_pool(&self, pool: &Pool) -> (Vec<f32>, Vec<f32>) {
        let d = self.last_dim();
        if pool.threads() <= 1 || self.data.len() < PAR_MIN_ELEMS || d == 0 {
            return self.lane_min_max();
        }
        let rows = self.data.len() / d;
        let rows_per = rows.div_ceil(pool.threads()).max(1);
        let blocks: Vec<&[f32]> = self.data.chunks(rows_per * d).collect();
        let partials = pool.par_map(&blocks, |_, block| {
            let mut lo = vec![f32::INFINITY; d];
            let mut hi = vec![f32::NEG_INFINITY; d];
            for row in block.chunks_exact(d) {
                for (j, &x) in row.iter().enumerate() {
                    if x < lo[j] {
                        lo[j] = x;
                    }
                    if x > hi[j] {
                        hi[j] = x;
                    }
                }
            }
            (lo, hi)
        });
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for (blo, bhi) in partials {
            for j in 0..d {
                if blo[j] < lo[j] {
                    lo[j] = blo[j];
                }
                if bhi[j] > hi[j] {
                    hi[j] = bhi[j];
                }
            }
        }
        (lo, hi)
    }

    /// Pool-parallel whole-tensor (min, max) in one pass. Empty tensors
    /// return (∞, -∞) like the serial `min()`/`max()` folds.
    pub fn min_max_pool(&self, pool: &Pool) -> (f32, f32) {
        if pool.threads() <= 1 || self.data.len() < PAR_MIN_ELEMS {
            return (self.min(), self.max());
        }
        let per = self.data.len().div_ceil(pool.threads()).max(1);
        let blocks: Vec<&[f32]> = self.data.chunks(per).collect();
        let partials = pool.par_map(&blocks, |_, block| {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &x in *block {
                lo = lo.min(x);
                hi = hi.max(x);
            }
            (lo, hi)
        });
        partials
            .into_iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(alo, ahi), (lo, hi)| {
                (alo.min(lo), ahi.max(hi))
            })
    }

    /// Per-row (all-but-last-axis) min and max — paper Fig. 2a per-token
    /// ranges.
    pub fn row_min_max(&self) -> (Vec<f32>, Vec<f32>) {
        let d = self.last_dim();
        self.data
            .chunks_exact(d)
            .map(|row| {
                (
                    row.iter().copied().fold(f32::INFINITY, f32::min),
                    row.iter().copied().fold(f32::NEG_INFINITY, f32::max),
                )
            })
            .unzip()
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        })
    }

    /// Mean squared difference.
    pub fn mse(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        if self.data.is_empty() {
            return Ok(0.0);
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / self.data.len() as f32)
    }

    /// 2-D matmul: (m, k) x (k, n) -> (m, n). Blocked over k for cache
    /// friendliness; used by AdaRound reconstruction.
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 {
            bail!("matmul wants 2-D tensors");
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        if k != k2 {
            bail!("matmul inner dim mismatch {k} vs {k2}");
        }
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (l, &a) in arow.iter().enumerate() {
                let brow = &other.data[l * n..(l + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// Pool-parallel [`Tensor::matmul`]: output rows are partitioned
    /// across workers; each row's dot products run in the same order as
    /// the serial kernel, so results are bit-identical for any worker
    /// count.
    pub fn matmul_pool(&self, other: &Tensor, pool: &Pool) -> Result<Tensor> {
        if self.shape.len() != 2 || other.shape.len() != 2 {
            bail!("matmul wants 2-D tensors");
        }
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        if k != k2 {
            bail!("matmul inner dim mismatch {k} vs {k2}");
        }
        if pool.threads() <= 1 || m * n < PAR_MIN_ELEMS {
            return self.matmul(other);
        }
        let mut out = vec![0.0f32; m * n];
        let rows_per = m.div_ceil(pool.threads()).max(1);
        pool.par_chunks_mut(&mut out, rows_per * n, |bi, block| {
            let r0 = bi * rows_per;
            for (ri, orow) in block.chunks_exact_mut(n).enumerate() {
                let i = r0 + ri;
                let arow = &self.data[i * k..(i + 1) * k];
                for (l, &a) in arow.iter().enumerate() {
                    let brow = &other.data[l * n..(l + 1) * n];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            }
        });
        Tensor::new(vec![m, n], out)
    }

    /// Transpose a 2-D tensor.
    pub fn transpose2(&self) -> Result<Tensor> {
        if self.shape.len() != 2 {
            bail!("transpose2 wants 2-D");
        }
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Gather rows of a 2-D tensor into a new order (permutes axis 0).
    pub fn permute_rows(&self, perm: &[usize]) -> Result<Tensor> {
        if self.shape.len() != 2 || perm.len() != self.shape[0] {
            bail!("permute_rows wants 2-D with matching perm");
        }
        let n = self.shape[1];
        let mut out = Vec::with_capacity(self.data.len());
        for &p in perm {
            out.extend_from_slice(&self.data[p * n..(p + 1) * n]);
        }
        Tensor::new(self.shape.clone(), out)
    }
}

/// Dense row-major i32 tensor (token ids, labels).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<IntTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(IntTensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> IntTensor {
        IntTensor { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn lane_min_max_works() {
        let t = Tensor::new(vec![2, 3], vec![1., -2., 3., 4., 0., -6.]).unwrap();
        let (lo, hi) = t.lane_min_max();
        assert_eq!(lo, vec![1., -2., -6.]);
        assert_eq!(hi, vec![4., 0., 3.]);
    }

    #[test]
    fn row_min_max_works() {
        let t = Tensor::new(vec![2, 3], vec![1., -2., 3., 4., 0., -6.]).unwrap();
        let (lo, hi) = t.row_min_max();
        assert_eq!(lo, vec![-2., -6.]);
        assert_eq!(hi, vec![3., 4.]);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(vec![2, 2], vec![1., 1., 1., 1.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_rejects_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let t = a.transpose2().unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.transpose2().unwrap(), a);
    }

    #[test]
    fn permute_rows_works() {
        let a = Tensor::new(vec![3, 2], vec![0., 0., 1., 1., 2., 2.]).unwrap();
        let p = a.permute_rows(&[2, 0, 1]).unwrap();
        assert_eq!(p.data(), &[2., 2., 0., 0., 1., 1.]);
    }

    #[test]
    fn mse_and_stats() {
        let a = Tensor::new(vec![4], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(vec![4], vec![1., 2., 3., 6.]).unwrap();
        assert!((a.mse(&b).unwrap() - 1.0).abs() < 1e-6);
        assert!((a.mean() - 2.5).abs() < 1e-6);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
        assert_eq!(b.abs_max(), 6.0);
    }

    #[test]
    fn scalar_tensor() {
        let s = Tensor::scalar(3.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.rows(), 1);
        assert_eq!(s.last_dim(), 1);
    }
}
