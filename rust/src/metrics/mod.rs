//! Evaluation metrics matching the GLUE benchmark (Wang et al., 2018):
//! accuracy, F1, Matthews correlation (CoLA), Pearson & Spearman
//! correlation (STS-B), and the combined per-task scores the paper's
//! tables report (acc/F1 mean for MRPC & QQP, Pearson/Spearman mean for
//! STS-B). All scores are reported ×100 as in the paper.

/// Classification accuracy.
pub fn accuracy(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(gold).filter(|(a, b)| a == b).count();
    hits as f64 / pred.len() as f64
}

/// Binary F1 with positive class = 1.
pub fn f1_binary(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let (mut tp, mut fp, mut fner) = (0.0, 0.0, 0.0);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fner += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let prec = tp / (tp + fp);
    let rec = tp / (tp + fner);
    2.0 * prec * rec / (prec + rec)
}

/// Matthews correlation coefficient (CoLA's metric).
pub fn matthews(pred: &[usize], gold: &[usize]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    let (mut tp, mut tn, mut fp, mut fner) = (0.0f64, 0.0, 0.0, 0.0);
    for (&p, &g) in pred.iter().zip(gold) {
        match (p, g) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fner += 1.0,
            _ => {}
        }
    }
    let denom = ((tp + fp) * (tp + fner) * (tn + fp) * (tn + fner)).sqrt();
    if denom == 0.0 {
        0.0
    } else {
        (tp * tn - fp * fner) / denom
    }
}

/// Pearson correlation.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let (mut cov, mut va, mut vb) = (0.0, 0.0, 0.0);
    for (&x, &y) in a.iter().zip(b) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va == 0.0 || vb == 0.0 {
        0.0
    } else {
        cov / (va.sqrt() * vb.sqrt())
    }
}

/// Average ranks (ties get the mean rank).
fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal));
    let mut out = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let mean_rank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            out[k] = mean_rank;
        }
        i = j + 1;
    }
    out
}

/// Spearman rank correlation.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    pearson(&ranks(a), &ranks(b))
}

/// Per-task combined score ×100 as reported in the paper's tables.
pub fn task_score(task: &str, pred_cls: &[usize], gold_cls: &[usize],
                  pred_reg: &[f64], gold_reg: &[f64]) -> f64 {
    100.0
        * match task {
            "cola" => matthews(pred_cls, gold_cls),
            "stsb" => {
                0.5 * (pearson(pred_reg, gold_reg) + spearman(pred_reg, gold_reg))
            }
            "mrpc" | "qqp" => {
                0.5 * (accuracy(pred_cls, gold_cls) + f1_binary(pred_cls, gold_cls))
            }
            _ => accuracy(pred_cls, gold_cls),
        }
}

/// GLUE macro-average over the 8 tasks (paper's final column).
pub fn glue_score(per_task: &[f64]) -> f64 {
    if per_task.is_empty() {
        return 0.0;
    }
    per_task.iter().sum::<f64>() / per_task.len() as f64
}

/// Median of a slice (the paper reports medians over seeds).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{prop_assert, prop_check};

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn f1_known_value() {
        // tp=2 fp=1 fn=1 -> p=2/3 r=2/3 f1=2/3
        let pred = [1, 1, 1, 0, 0];
        let gold = [1, 1, 0, 1, 0];
        assert!((f1_binary(&pred, &gold) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_perfect_and_inverse() {
        let g = [0, 1, 0, 1, 1, 0];
        assert!((matthews(&g, &g) - 1.0).abs() < 1e-9);
        let inv: Vec<usize> = g.iter().map(|&x| 1 - x).collect();
        assert!((matthews(&inv, &g) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn matthews_degenerate_is_zero() {
        assert_eq!(matthews(&[1, 1, 1], &[1, 0, 1]), 0.0);
    }

    #[test]
    fn pearson_linear() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.0, 8.0, 27.0, 64.0, 125.0]; // cubic: rank corr = 1
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 1.0, 2.0];
        let b = [3.0, 3.0, 5.0];
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn task_score_dispatch() {
        let p = [1usize, 0, 1, 1];
        let g = [1usize, 0, 0, 1];
        assert!((task_score("sst2", &p, &g, &[], &[]) - 75.0).abs() < 1e-9);
        let s = task_score("mrpc", &p, &g, &[], &[]);
        let expect = 100.0 * 0.5 * (0.75 + f1_binary(&p, &g));
        assert!((s - expect).abs() < 1e-9);
    }

    #[test]
    fn prop_metrics_bounded() {
        prop_check("metrics in [-1,1]", 100, |rng| {
            let n = 3 + rng.below(50);
            let pred: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
            let gold: Vec<usize> = (0..n).map(|_| rng.below(2)).collect();
            let a: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
            prop_assert(accuracy(&pred, &gold) <= 1.0, "acc > 1")?;
            prop_assert(f1_binary(&pred, &gold) <= 1.0, "f1 > 1")?;
            prop_assert(matthews(&pred, &gold).abs() <= 1.0 + 1e-9, "mcc")?;
            prop_assert(pearson(&a, &b).abs() <= 1.0 + 1e-9, "pearson")?;
            prop_assert(spearman(&a, &b).abs() <= 1.0 + 1e-9, "spearman")?;
            Ok(())
        });
    }

    #[test]
    fn prop_pearson_shift_scale_invariant() {
        prop_check("pearson invariance", 50, |rng| {
            let n = 5 + rng.below(30);
            let a: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let scale = 0.5 + rng.f64() * 3.0;
            let shift = rng.f64() * 5.0 - 2.5;
            let b2: Vec<f64> = b.iter().map(|&x| x * scale + shift).collect();
            let p1 = pearson(&a, &b);
            let p2 = pearson(&a, &b2);
            prop_assert((p1 - p2).abs() < 1e-7, format!("{p1} vs {p2}"))
        });
    }
}
