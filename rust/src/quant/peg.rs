//! Per-embedding-group (PEG) quantization — the paper's novel contribution
//! (§4, Eq. 5): split the embedding axis into K groups, share quantization
//! parameters within each group, and optionally apply the *range-based
//! permutation* so all outlier dims land in the same group.
//!
//! The output of this module is a per-lane (scale, zero-point) vector: the
//! L2 graphs consume per-dim vectors, so "PEG with permutation" is realised
//! by writing each group's shared parameters into that group's (permuted)
//! member lanes — mathematically identical to the split/concat rewrite of
//! paper Fig. 4, with zero graph changes.

use anyhow::{bail, Result};

use super::{qparams_from_range, Granularity, QGrid, QParams};

/// Deterministic range-based permutation: lanes sorted by ascending dynamic
/// range (paper §4: "K evenly sized groups based on indices in
/// argsort(r)").
pub fn range_permutation(lo: &[f32], hi: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..lo.len()).collect();
    idx.sort_by(|&a, &b| {
        let ra = hi[a] - lo[a];
        let rb = hi[b] - lo[b];
        ra.partial_cmp(&rb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx
}

/// Evenly sized group boundaries: group g covers sorted positions
/// [g*d/K, (g+1)*d/K).
pub fn group_bounds(d: usize, k: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(k);
    for g in 0..k {
        out.push((g * d / k, (g + 1) * d / k));
    }
    out
}

/// Compute the per-lane QParams vector for a site with per-lane ranges
/// (lo, hi), at the requested granularity.
///
/// Returns (params, perm) where `perm` is the range-based permutation used
/// (identity when not permuting) — reported so the simulation-on-per-tensor
/// -hardware path (paper Fig. 4) can materialise it.
pub fn lane_qparams(
    lo: &[f32],
    hi: &[f32],
    gran: &Granularity,
    grid: QGrid,
) -> Result<(Vec<QParams>, Vec<usize>)> {
    let d = lo.len();
    if hi.len() != d {
        bail!("lo/hi length mismatch");
    }
    let identity: Vec<usize> = (0..d).collect();
    match gran {
        Granularity::PerTensor => {
            let tlo = lo.iter().copied().fold(f32::INFINITY, f32::min);
            let thi = hi.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let p = qparams_from_range(tlo, thi, grid);
            Ok((vec![p; d], identity))
        }
        Granularity::PerEmbedding => {
            let params = lo
                .iter()
                .zip(hi)
                .map(|(&l, &h)| qparams_from_range(l, h, grid))
                .collect();
            Ok((params, identity))
        }
        Granularity::PerEmbeddingGroup { k, permute } => {
            let k = (*k).max(1);
            if d % k != 0 {
                bail!("K={k} must divide d={d}");
            }
            let order = if *permute {
                range_permutation(lo, hi)
            } else {
                identity.clone()
            };
            let mut params = vec![QParams { scale: 1.0, zero_point: 0.0 }; d];
            for (g0, g1) in group_bounds(d, k) {
                let members = &order[g0..g1];
                let glo = members
                    .iter()
                    .map(|&j| lo[j])
                    .fold(f32::INFINITY, f32::min);
                let ghi = members
                    .iter()
                    .map(|&j| hi[j])
                    .fold(f32::NEG_INFINITY, f32::max);
                let p = qparams_from_range(glo, ghi, grid);
                for &j in members {
                    params[j] = p;
                }
            }
            Ok((params, order))
        }
    }
}

/// Memory overhead of PEG for one attention layer, in extra parameters —
/// the paper's d + 2*3*K accounting (§4): permutation indices plus scale &
/// zero-point per group for FFN input, output and sum.
pub fn peg_overhead_params(d: usize, k: usize) -> usize {
    d + 2 * 3 * k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{qdq_per_lane, Estimator};
    use crate::quant::estimators::RangeTracker;
    use crate::tensor::Tensor;
    use crate::util::prop::{prop_assert, prop_check};
    use crate::util::rng::Rng;

    #[test]
    fn permutation_sorts_by_range() {
        let lo = vec![0.0, -5.0, 0.0, -0.1];
        let hi = vec![1.0, 5.0, 0.5, 0.1];
        let p = range_permutation(&lo, &hi);
        assert_eq!(p, vec![3, 2, 0, 1]); // ranges 0.2, 0.5, 1.0, 10.0
    }

    #[test]
    fn group_bounds_even() {
        assert_eq!(group_bounds(6, 3), vec![(0, 2), (2, 4), (4, 6)]);
        assert_eq!(group_bounds(8, 1), vec![(0, 8)]);
    }

    #[test]
    fn k1_equals_per_tensor() {
        let lo = vec![-1.0, -2.0, 0.0, -0.5];
        let hi = vec![1.0, 3.0, 0.2, 0.5];
        let grid = QGrid::asymmetric(8);
        let (pt, _) = lane_qparams(&lo, &hi, &Granularity::PerTensor, grid).unwrap();
        let (k1, _) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 1, permute: false },
            grid,
        )
        .unwrap();
        assert_eq!(pt, k1);
    }

    #[test]
    fn kd_equals_per_embedding() {
        let lo = vec![-1.0, -2.0, 0.0, -0.5];
        let hi = vec![1.0, 3.0, 0.2, 0.5];
        let grid = QGrid::asymmetric(8);
        let (pe, _) = lane_qparams(&lo, &hi, &Granularity::PerEmbedding, grid).unwrap();
        let (kd, _) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 4, permute: false },
            grid,
        )
        .unwrap();
        assert_eq!(pe, kd);
    }

    #[test]
    fn rejects_non_dividing_k() {
        let lo = vec![0.0; 10];
        let hi = vec![1.0; 10];
        assert!(lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 3, permute: false },
            QGrid::asymmetric(8)
        )
        .is_err());
    }

    #[test]
    fn permutation_isolates_outliers() {
        // 16 lanes, 2 adjacent-but-separated outlier lanes; K=8 with
        // permutation puts both in the top group -> the other groups get
        // tight scales
        let mut lo = vec![-0.5f32; 16];
        let mut hi = vec![0.5f32; 16];
        lo[3] = -40.0;
        hi[3] = 40.0;
        lo[12] = -38.0;
        hi[12] = 38.0;
        let grid = QGrid::asymmetric(8);
        let (params, order) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 8, permute: true },
            grid,
        )
        .unwrap();
        // outliers sorted last (their relative order is by range)
        let mut tail = order[14..].to_vec();
        tail.sort();
        assert_eq!(tail, vec![3, 12]);
        // non-outlier lanes get a small scale
        for j in 0..16 {
            if j == 3 || j == 12 {
                assert!(params[j].scale > 0.1);
            } else {
                assert!(params[j].scale < 0.01, "lane {j} scale {}", params[j].scale);
            }
        }
    }

    #[test]
    fn permuted_groups_beat_unpermuted_on_split_outliers() {
        // The Table 5 mechanism: K=3+P ~ K=6+P >> K=3 without P when the
        // outlier dims are scattered.
        let mut rng = Rng::new(11);
        let d = 12;
        let rows = 64;
        let mut data = vec![0.0f32; rows * d];
        for (i, x) in data.iter_mut().enumerate() {
            let col = i % d;
            let mag = if col == 1 || col == 10 { 50.0 } else { 0.8 };
            *x = rng.uniform(-mag, mag);
        }
        let t = Tensor::new(vec![rows, d], data).unwrap();
        let grid = QGrid::asymmetric(8);
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, d);
        tr.observe(&t).unwrap();
        let (lo, hi) = tr.lane_ranges();

        let err = |gran: Granularity| {
            let (params, _) = lane_qparams(&lo, &hi, &gran, grid).unwrap();
            qdq_per_lane(&t, &params, grid).unwrap().mse(&t).unwrap()
        };
        // without P: both outlier cols land in different groups, polluting
        // 8 of 12 lanes; with P they share one group, polluting 4.
        let e_plain = err(Granularity::PerEmbeddingGroup { k: 3, permute: false });
        let e_perm = err(Granularity::PerEmbeddingGroup { k: 3, permute: true });
        let e_pe = err(Granularity::PerEmbedding);
        assert!(e_perm < e_plain * 0.6, "perm {e_perm} vs plain {e_plain}");
        assert!(e_pe <= e_perm * 1.01);
    }

    #[test]
    fn overhead_matches_paper_accounting() {
        // paper: "d + 2*3*K extra parameters per attention layer ...
        // less than 0.04% of BERT-base"
        let per_layer = peg_overhead_params(768, 6);
        assert_eq!(per_layer, 768 + 36);
        let total = per_layer * 12;
        assert!((total as f64) < 0.0004 * 109e6);
    }

    #[test]
    fn prop_grouped_scales_cover_member_ranges() {
        prop_check("peg covers", 100, |rng| {
            let d = 16;
            let k = [1usize, 2, 4, 8, 16][rng.below(5)];
            let lo: Vec<f32> = (0..d).map(|_| rng.uniform(-10.0, 0.0)).collect();
            let hi: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 10.0)).collect();
            let grid = QGrid::asymmetric(8);
            let permute = rng.bool(0.5);
            let (params, _) =
                lane_qparams(&lo, &hi, &Granularity::PerEmbeddingGroup { k, permute }, grid)
                    .unwrap();
            // every lane's scale must cover its own range: s*levels >= hi-lo
            for j in 0..d {
                let covered = params[j].scale * grid.levels() + 1e-4;
                prop_assert(
                    covered >= hi[j] - lo[j],
                    format!("lane {j}: scale {} covers {covered} < {}", params[j].scale,
                            hi[j] - lo[j]),
                )?;
            }
            Ok(())
        });
    }
}
