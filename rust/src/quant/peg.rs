//! Per-embedding-group (PEG) quantization — the paper's novel contribution
//! (§4, Eq. 5): split the embedding axis into K groups, share quantization
//! parameters within each group, and optionally apply the *range-based
//! permutation* so all outlier dims land in the same group.
//!
//! The output of this module is a per-lane (scale, zero-point) vector: the
//! L2 graphs consume per-dim vectors, so "PEG with permutation" is realised
//! by writing each group's shared parameters into that group's (permuted)
//! member lanes — mathematically identical to the split/concat rewrite of
//! paper Fig. 4, with zero graph changes.

use anyhow::{bail, Result};

use super::{qparams_from_range, Granularity, QGrid, QParams};

/// Deterministic range-based permutation: lanes sorted by ascending dynamic
/// range (paper §4: "K evenly sized groups based on indices in
/// argsort(r)").
///
/// Total for *any* input: a lane whose range is NaN (NaN statistics) is
/// treated as infinitely wide, so degenerate lanes sort last with the
/// outliers and the comparator stays a total order (`sort_by` may panic
/// on a non-transitive comparator, which the old
/// `partial_cmp(..).unwrap_or(Equal)` tiebreak was for mixed NaN/finite
/// inputs). Ties break by lane index, so the permutation is always a
/// valid, deterministic rearrangement of `0..d`.
pub fn range_permutation(lo: &[f32], hi: &[f32]) -> Vec<usize> {
    let range = |j: usize| {
        let r = hi[j] - lo[j];
        // `+ 0.0` normalises -0.0 so equal-width lanes compare Equal
        if r.is_nan() { f32::INFINITY } else { r + 0.0 }
    };
    let mut idx: Vec<usize> = (0..lo.len()).collect();
    idx.sort_by(|&a, &b| range(a).total_cmp(&range(b)).then(a.cmp(&b)));
    idx
}

/// (Nearly) evenly sized group boundaries: group g covers sorted
/// positions [g*d/K, (g+1)*d/K). For any `1 <= k <= d` the boundaries
/// partition `0..d` exactly, with group sizes differing by at most one
/// when K does not divide d.
pub fn group_bounds(d: usize, k: usize) -> Vec<(usize, usize)> {
    let mut out = Vec::with_capacity(k);
    for g in 0..k {
        out.push((g * d / k, (g + 1) * d / k));
    }
    out
}

/// Decompose a site's `d` lanes into parameter-sharing groups for a
/// granularity. Returns `(groups, order)`: `groups[g]` lists the member
/// lanes of group `g` (in permuted order), and `order` is the lane
/// permutation the grouping was built over — the identity unless the
/// granularity asks for the range-based permutation. Group counts clamp
/// to `1..=d`, so K=1 degrades to per-tensor and K>=d to per-embedding.
pub fn site_groups(
    lo: &[f32],
    hi: &[f32],
    gran: &Granularity,
) -> Result<(Vec<Vec<usize>>, Vec<usize>)> {
    let d = lo.len();
    if hi.len() != d {
        bail!("lo/hi length mismatch: {} vs {}", d, hi.len());
    }
    let identity: Vec<usize> = (0..d).collect();
    let (order, k) = match gran {
        Granularity::PerTensor => (identity, 1),
        Granularity::PerEmbedding => (identity, d.max(1)),
        Granularity::PerEmbeddingGroup { k, permute } => {
            let k = (*k).clamp(1, d.max(1));
            let order = if *permute { range_permutation(lo, hi) } else { identity };
            (order, k)
        }
    };
    let groups = group_bounds(d, k)
        .into_iter()
        .map(|(g0, g1)| order[g0..g1].to_vec())
        .collect();
    Ok((groups, order))
}

/// Compute the per-lane QParams vector for a site with per-lane ranges
/// (lo, hi), at the requested granularity.
///
/// Returns (params, perm) where `perm` is the range-based permutation used
/// (identity when not permuting) — reported so the simulation-on-per-tensor
/// -hardware path (paper Fig. 4) can materialise it.
pub fn lane_qparams(
    lo: &[f32],
    hi: &[f32],
    gran: &Granularity,
    grid: QGrid,
) -> Result<(Vec<QParams>, Vec<usize>)> {
    let (groups, order) = site_groups(lo, hi, gran)?;
    let d = lo.len();
    let mut params = vec![QParams { scale: 1.0, zero_point: 0.0 }; d];
    for members in &groups {
        let glo = members.iter().map(|&j| lo[j]).fold(f32::INFINITY, f32::min);
        let ghi = members.iter().map(|&j| hi[j]).fold(f32::NEG_INFINITY, f32::max);
        let p = qparams_from_range(glo, ghi, grid);
        for &j in members {
            params[j] = p;
        }
    }
    Ok((params, order))
}

/// Memory overhead of PEG for one attention layer, in extra parameters —
/// the paper's d + 2*3*K accounting (§4): permutation indices plus scale &
/// zero-point per group for FFN input, output and sum.
pub fn peg_overhead_params(d: usize, k: usize) -> usize {
    d + 2 * 3 * k
}

/// The same per-attention-layer accounting generalised over granularities
/// (the sweep's overhead column): per-tensor is the zero baseline,
/// per-embedding stores 2 parameters per lane for the 3 FFN sites (no
/// permutation needed — every lane already has its own), and PEG stores 2
/// per group per site plus the d permutation indices when the range-based
/// permutation is on. `granularity_overhead_params(d, PEG{k, permute:
/// true})` equals [`peg_overhead_params`]`(d, k)`.
pub fn granularity_overhead_params(d: usize, gran: &Granularity) -> usize {
    match gran {
        Granularity::PerTensor => 0,
        Granularity::PerEmbedding => 2 * 3 * d,
        Granularity::PerEmbeddingGroup { k, permute } => {
            let k = (*k).clamp(1, d.max(1));
            2 * 3 * k + if *permute { d } else { 0 }
        }
    }
}

/// Overhead of ONE site with `channels` lanes, vs the per-tensor
/// baseline of a single (scale, zero-point) pair: 2 extra parameters per
/// additional group, plus the permutation indices when the range-based
/// permutation is on. This is the `repro run --explain` per-site column;
/// [`granularity_overhead_params`] is the paper's per-attention-layer
/// roll-up (3 sites, each group's pair counted, permutation shared once
/// per layer) used by the sweep's overhead column.
pub fn site_overhead_params(channels: usize, gran: &Granularity) -> usize {
    match gran {
        Granularity::PerTensor => 0,
        Granularity::PerEmbedding => 2 * channels.saturating_sub(1),
        Granularity::PerEmbeddingGroup { k, permute } => {
            let k = (*k).clamp(1, channels.max(1));
            2 * (k - 1) + if *permute { channels } else { 0 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{qdq_per_lane, Estimator};
    use crate::quant::estimators::RangeTracker;
    use crate::tensor::Tensor;
    use crate::util::prop::{prop_assert, prop_check};
    use crate::util::rng::Rng;

    #[test]
    fn permutation_sorts_by_range() {
        let lo = vec![0.0, -5.0, 0.0, -0.1];
        let hi = vec![1.0, 5.0, 0.5, 0.1];
        let p = range_permutation(&lo, &hi);
        assert_eq!(p, vec![3, 2, 0, 1]); // ranges 0.2, 0.5, 1.0, 10.0
    }

    #[test]
    fn group_bounds_even() {
        assert_eq!(group_bounds(6, 3), vec![(0, 2), (2, 4), (4, 6)]);
        assert_eq!(group_bounds(8, 1), vec![(0, 8)]);
    }

    #[test]
    fn group_bounds_uneven_partitions_exactly() {
        // K need not divide d: boundaries still tile 0..d with sizes
        // differing by at most one
        for (d, k) in [(10usize, 3usize), (128, 6), (128, 12), (7, 5)] {
            let bounds = group_bounds(d, k);
            assert_eq!(bounds.len(), k);
            assert_eq!(bounds[0].0, 0);
            assert_eq!(bounds[k - 1].1, d);
            let mut sizes = Vec::new();
            for w in bounds.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap/overlap at {w:?}");
            }
            for (a, b) in &bounds {
                assert!(a <= b);
                sizes.push(b - a);
            }
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "d={d} K={k} sizes {sizes:?}");
        }
    }

    #[test]
    fn site_groups_shapes() {
        let lo = vec![-1.0f32; 8];
        let hi = vec![1.0f32; 8];
        let (g_pt, ord) = site_groups(&lo, &hi, &Granularity::PerTensor).unwrap();
        assert_eq!(g_pt, vec![(0..8).collect::<Vec<_>>()]);
        assert_eq!(ord, (0..8).collect::<Vec<_>>());
        let (g_pe, _) = site_groups(&lo, &hi, &Granularity::PerEmbedding).unwrap();
        assert_eq!(g_pe.len(), 8);
        assert!(g_pe.iter().enumerate().all(|(j, g)| g == &vec![j]));
        // K clamps into 1..=d instead of erroring
        let (g_big, _) = site_groups(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 99, permute: false },
        )
        .unwrap();
        assert_eq!(g_big.len(), 8);
        assert!(site_groups(&lo, &hi[..4], &Granularity::PerTensor).is_err());
    }

    #[test]
    fn k1_equals_per_tensor() {
        let lo = vec![-1.0, -2.0, 0.0, -0.5];
        let hi = vec![1.0, 3.0, 0.2, 0.5];
        let grid = QGrid::asymmetric(8);
        let (pt, _) = lane_qparams(&lo, &hi, &Granularity::PerTensor, grid).unwrap();
        let (k1, _) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 1, permute: false },
            grid,
        )
        .unwrap();
        assert_eq!(pt, k1);
    }

    #[test]
    fn kd_equals_per_embedding() {
        let lo = vec![-1.0, -2.0, 0.0, -0.5];
        let hi = vec![1.0, 3.0, 0.2, 0.5];
        let grid = QGrid::asymmetric(8);
        let (pe, _) = lane_qparams(&lo, &hi, &Granularity::PerEmbedding, grid).unwrap();
        let (kd, _) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 4, permute: false },
            grid,
        )
        .unwrap();
        assert_eq!(pe, kd);
    }

    #[test]
    fn non_dividing_k_uses_near_even_groups() {
        // 10 lanes in 3 groups: sizes 3/3/4, every lane covered exactly once
        let lo: Vec<f32> = (0..10).map(|j| -(j as f32) - 1.0).collect();
        let hi: Vec<f32> = (0..10).map(|j| (j as f32) + 1.0).collect();
        let (params, order) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 3, permute: false },
            QGrid::asymmetric(8),
        )
        .unwrap();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
        // group maxima widen monotonically: lanes 0..3 share lane 2's
        // range, 3..6 lane 5's, 6..10 lane 9's
        assert_eq!(params[0], params[2]);
        assert_eq!(params[3], params[5]);
        assert_eq!(params[6], params[9]);
        assert!(params[0].scale < params[3].scale);
        assert!(params[3].scale < params[6].scale);
    }

    #[test]
    fn permutation_is_total_on_nan_and_inf_lanes() {
        // NaN/inf statistics must not break the sort (the old partial_cmp
        // tiebreak was non-transitive on mixed NaN/finite ranges)
        let lo = vec![0.0, f32::NAN, -1.0, f32::NEG_INFINITY, -0.5, 0.0];
        let hi = vec![5.0, f32::NAN, 1.0, 2.0, f32::INFINITY, 1.0];
        let p = range_permutation(&lo, &hi);
        let mut sorted = p.clone();
        sorted.sort();
        assert_eq!(sorted, (0..6).collect::<Vec<_>>(), "not a permutation: {p:?}");
        // non-finite-range lanes (1, 3, 4) sort after every finite lane
        let pos = |j: usize| p.iter().position(|&x| x == j).unwrap();
        for finite in [0usize, 2, 5] {
            for wild in [1usize, 3, 4] {
                assert!(pos(finite) < pos(wild), "lane {finite} after lane {wild}: {p:?}");
            }
        }
    }

    #[test]
    fn permutation_isolates_outliers() {
        // 16 lanes, 2 adjacent-but-separated outlier lanes; K=8 with
        // permutation puts both in the top group -> the other groups get
        // tight scales
        let mut lo = vec![-0.5f32; 16];
        let mut hi = vec![0.5f32; 16];
        lo[3] = -40.0;
        hi[3] = 40.0;
        lo[12] = -38.0;
        hi[12] = 38.0;
        let grid = QGrid::asymmetric(8);
        let (params, order) = lane_qparams(
            &lo,
            &hi,
            &Granularity::PerEmbeddingGroup { k: 8, permute: true },
            grid,
        )
        .unwrap();
        // outliers sorted last (their relative order is by range)
        let mut tail = order[14..].to_vec();
        tail.sort();
        assert_eq!(tail, vec![3, 12]);
        // non-outlier lanes get a small scale
        for j in 0..16 {
            if j == 3 || j == 12 {
                assert!(params[j].scale > 0.1);
            } else {
                assert!(params[j].scale < 0.01, "lane {j} scale {}", params[j].scale);
            }
        }
    }

    #[test]
    fn permuted_groups_beat_unpermuted_on_split_outliers() {
        // The Table 5 mechanism: K=3+P ~ K=6+P >> K=3 without P when the
        // outlier dims are scattered.
        let mut rng = Rng::new(11);
        let d = 12;
        let rows = 64;
        let mut data = vec![0.0f32; rows * d];
        for (i, x) in data.iter_mut().enumerate() {
            let col = i % d;
            let mag = if col == 1 || col == 10 { 50.0 } else { 0.8 };
            *x = rng.uniform(-mag, mag);
        }
        let t = Tensor::new(vec![rows, d], data).unwrap();
        let grid = QGrid::asymmetric(8);
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, d);
        tr.observe(&t).unwrap();
        let (lo, hi) = tr.lane_ranges();

        let err = |gran: Granularity| {
            let (params, _) = lane_qparams(&lo, &hi, &gran, grid).unwrap();
            qdq_per_lane(&t, &params, grid).unwrap().mse(&t).unwrap()
        };
        // without P: both outlier cols land in different groups, polluting
        // 8 of 12 lanes; with P they share one group, polluting 4.
        let e_plain = err(Granularity::PerEmbeddingGroup { k: 3, permute: false });
        let e_perm = err(Granularity::PerEmbeddingGroup { k: 3, permute: true });
        let e_pe = err(Granularity::PerEmbedding);
        assert!(e_perm < e_plain * 0.6, "perm {e_perm} vs plain {e_plain}");
        assert!(e_pe <= e_perm * 1.01);
    }

    #[test]
    fn overhead_matches_paper_accounting() {
        // paper: "d + 2*3*K extra parameters per attention layer ...
        // less than 0.04% of BERT-base"
        let per_layer = peg_overhead_params(768, 6);
        assert_eq!(per_layer, 768 + 36);
        let total = per_layer * 12;
        assert!((total as f64) < 0.0004 * 109e6);
    }

    #[test]
    fn granularity_overhead_generalises_peg_accounting() {
        let d = 768;
        assert_eq!(granularity_overhead_params(d, &Granularity::PerTensor), 0);
        assert_eq!(granularity_overhead_params(d, &Granularity::PerEmbedding), 6 * d);
        for k in [3usize, 6, 12] {
            assert_eq!(
                granularity_overhead_params(
                    d,
                    &Granularity::PerEmbeddingGroup { k, permute: true }
                ),
                peg_overhead_params(d, k)
            );
            assert_eq!(
                granularity_overhead_params(
                    d,
                    &Granularity::PerEmbeddingGroup { k, permute: false }
                ),
                6 * k
            );
        }
    }

    #[test]
    fn site_overhead_baseline_is_one_pair() {
        // one site, vs the single per-tensor (scale, zp) pair
        let d = 128;
        assert_eq!(site_overhead_params(d, &Granularity::PerTensor), 0);
        assert_eq!(site_overhead_params(d, &Granularity::PerEmbedding), 2 * (d - 1));
        assert_eq!(
            site_overhead_params(d, &Granularity::PerEmbeddingGroup { k: 6, permute: false }),
            10
        );
        assert_eq!(
            site_overhead_params(d, &Granularity::PerEmbeddingGroup { k: 6, permute: true }),
            10 + d
        );
        // K=1 without permutation is exactly the per-tensor baseline
        assert_eq!(
            site_overhead_params(d, &Granularity::PerEmbeddingGroup { k: 1, permute: false }),
            0
        );
        // degenerate sites never underflow
        assert_eq!(site_overhead_params(0, &Granularity::PerEmbedding), 0);
    }

    #[test]
    fn prop_grouped_scales_cover_member_ranges() {
        prop_check("peg covers", 100, |rng| {
            let d = 16;
            // any K in 1..=d, dividing or not
            let k = 1 + rng.below(d);
            let lo: Vec<f32> = (0..d).map(|_| rng.uniform(-10.0, 0.0)).collect();
            let hi: Vec<f32> = (0..d).map(|_| rng.uniform(0.0, 10.0)).collect();
            let grid = QGrid::asymmetric(8);
            let permute = rng.bool(0.5);
            let (params, _) =
                lane_qparams(&lo, &hi, &Granularity::PerEmbeddingGroup { k, permute }, grid)
                    .unwrap();
            // every lane's scale must cover its own range: s*levels >= hi-lo
            for j in 0..d {
                let covered = params[j].scale * grid.levels() + 1e-4;
                prop_assert(
                    covered >= hi[j] - lo[j],
                    format!("lane {j}: scale {} covers {covered} < {}", params[j].scale,
                            hi[j] - lo[j]),
                )?;
            }
            Ok(())
        });
    }
}
