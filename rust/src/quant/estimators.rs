//! Static range estimators (paper §2 "static range estimation"):
//! current min-max, running min-max (EMA), and MSE grid search.
//!
//! Estimators observe calibration batches *per lane* (last-axis channel) so
//! a single pass supports every downstream granularity: per-tensor ranges
//! reduce over lanes, PEG groups reduce over sorted lane subsets, and
//! per-embedding uses the lane stats directly.

use anyhow::{bail, Result};

use super::{qdq_one, qparams_from_range, Estimator, QGrid};
use crate::tensor::Tensor;
use crate::util::pool::Pool;

/// Below this sample count the MSE grid search stays serial — scoring 41
/// candidates over a small reservoir is cheaper than spawning workers.
const MSE_PAR_MIN_SAMPLES: usize = 1 << 12;

/// Momentum for running min-max (paper Appendix B.2 uses 0.9).
pub const RUNNING_MOMENTUM: f32 = 0.9;

/// Cap on retained samples for the MSE search. The reservoir is a
/// deterministic stride over the *whole* calibration stream: it fills at
/// stride 1, and whenever it reaches capacity it re-thins itself to every
/// other element and doubles the stride — so late batches are always
/// represented (invariant: `reservoir[i]` is stream element `i * stride`).
const MSE_RESERVOIR: usize = 1 << 16;

/// Cap on retained *rows* for the per-group MSE search (rows keep one
/// aligned value per lane, so the per-site memory is `ROW_RESERVOIR *
/// lanes` floats). Same deterministic stride + re-thinning scheme as
/// [`MSE_RESERVOIR`], over rows instead of values.
const ROW_RESERVOIR: usize = 1 << 11;

/// Accumulates per-lane range statistics over calibration batches.
#[derive(Debug, Clone)]
pub struct RangeTracker {
    pub kind: Estimator,
    lanes: usize,
    /// current per-lane mins/maxs (semantics depend on `kind`)
    lo: Vec<f32>,
    hi: Vec<f32>,
    batches_seen: usize,
    /// downsampled raw values for the MSE search
    reservoir: Vec<f32>,
    seen: usize,
    /// current sampling stride over the stream (power of two)
    stride: usize,
    /// retain per-lane row samples (set for sites whose resolved range
    /// method needs an MSE search the `kind` alone would not feed —
    /// `mse_group` always, `mse_tensor` under a non-MSE estimator)
    sample_rows: bool,
    /// row-major `(rows_kept, lanes)` buffer of retained rows
    lane_rows: Vec<f32>,
    rows_kept: usize,
    rows_seen: usize,
    /// current row-sampling stride over the stream (power of two)
    row_stride: usize,
}

impl RangeTracker {
    pub fn new(kind: Estimator, lanes: usize) -> RangeTracker {
        RangeTracker {
            kind,
            lanes,
            lo: vec![f32::INFINITY; lanes],
            hi: vec![f32::NEG_INFINITY; lanes],
            batches_seen: 0,
            reservoir: Vec::new(),
            seen: 0,
            stride: 1,
            sample_rows: false,
            lane_rows: Vec::new(),
            rows_kept: 0,
            rows_seen: 0,
            row_stride: 1,
        }
    }

    /// Builder: also retain per-lane row samples, feeding the per-group
    /// MSE grid search ([`mse_search_groups_pool`]) for any calibration
    /// estimator. The spec pipeline enables this automatically for sites
    /// resolved to a row-sampling range method.
    pub fn with_row_samples(mut self) -> RangeTracker {
        self.sample_rows = true;
        self
    }

    /// Whether this tracker retains per-lane row samples.
    pub fn has_row_samples(&self) -> bool {
        self.sample_rows
    }

    /// The retained rows as a row-major `(rows, lanes)` buffer plus the
    /// row count; `None` when row sampling was not enabled.
    pub fn row_samples(&self) -> Option<(&[f32], usize)> {
        if self.sample_rows {
            Some((&self.lane_rows, self.rows_kept))
        } else {
            None
        }
    }

    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn batches_seen(&self) -> usize {
        self.batches_seen
    }

    /// Observe one calibration batch of this site's activation tensor.
    pub fn observe(&mut self, t: &Tensor) -> Result<()> {
        self.observe_pool(t, Pool::global())
    }

    /// Pool-explicit [`RangeTracker::observe`]: the per-lane (or whole-
    /// tensor) min/max scan fans out across workers; min/max merges are
    /// exact, so ranges are bit-identical for any worker count.
    pub fn observe_pool(&mut self, t: &Tensor, pool: &Pool) -> Result<()> {
        if t.last_dim() != self.lanes && !(self.lanes == 1) {
            bail!("tracker lanes {} vs tensor lanes {}", self.lanes, t.last_dim());
        }
        let (blo, bhi) = if self.lanes == 1 {
            let (lo, hi) = t.min_max_pool(pool);
            (vec![lo], vec![hi])
        } else {
            t.lane_min_max_pool(pool)
        };
        match self.kind {
            Estimator::CurrentMinMax => {
                // "current": ranges of the most recent batch only
                self.lo = blo;
                self.hi = bhi;
            }
            Estimator::RunningMinMax => {
                if self.batches_seen == 0 {
                    self.lo = blo;
                    self.hi = bhi;
                } else {
                    let m = RUNNING_MOMENTUM;
                    for j in 0..self.lanes {
                        self.lo[j] = m * self.lo[j] + (1.0 - m) * blo[j];
                        self.hi[j] = m * self.hi[j] + (1.0 - m) * bhi[j];
                    }
                }
            }
            Estimator::Mse => {
                for j in 0..self.lanes {
                    self.lo[j] = self.lo[j].min(blo[j]);
                    self.hi[j] = self.hi[j].max(bhi[j]);
                }
                self.stash(t.data());
            }
        }
        if self.sample_rows {
            self.stash_rows(t);
        }
        self.batches_seen += 1;
        Ok(())
    }

    /// Deterministic stride over the whole stream. Earlier versions
    /// stopped sampling once the reservoir was full, so the MSE grid
    /// search only ever saw the first ~64k calibration values and later
    /// batches (and their outliers) were silently ignored. Now the
    /// reservoir re-thins itself (keep every other element, double the
    /// stride) whenever it fills, so every batch of the stream stays
    /// represented at equal density.
    fn stash(&mut self, xs: &[f32]) {
        for (i, &x) in xs.iter().enumerate() {
            let global = self.seen + i;
            if global == self.reservoir.len() * self.stride {
                self.reservoir.push(x);
                if self.reservoir.len() >= MSE_RESERVOIR {
                    let thinned: Vec<f32> =
                        self.reservoir.iter().copied().step_by(2).collect();
                    self.reservoir = thinned;
                    self.stride *= 2;
                }
            }
        }
        self.seen += xs.len();
    }

    /// Deterministic row-stride sampling mirroring [`RangeTracker::stash`]
    /// with rows (not values) as the unit, so every retained sample keeps
    /// one aligned value per lane — the per-group MSE search needs lane
    /// identity, which the flat reservoir discards. Scalar trackers
    /// (`lanes == 1`) treat every element as a width-1 row. Invariant:
    /// retained row `i` is stream row `i * row_stride`.
    fn stash_rows(&mut self, t: &Tensor) {
        let d = self.lanes;
        let rows = if d == 1 { t.len() } else { t.rows() };
        let data = t.data();
        for r in 0..rows {
            let global = self.rows_seen + r;
            if global == self.rows_kept * self.row_stride {
                self.lane_rows.extend_from_slice(&data[r * d..(r + 1) * d]);
                self.rows_kept += 1;
                if self.rows_kept >= ROW_RESERVOIR {
                    let mut thinned = Vec::with_capacity((self.rows_kept / 2 + 1) * d);
                    for keep in (0..self.rows_kept).step_by(2) {
                        thinned.extend_from_slice(&self.lane_rows[keep * d..(keep + 1) * d]);
                    }
                    self.lane_rows = thinned;
                    self.rows_kept = self.rows_kept.div_ceil(2);
                    self.row_stride *= 2;
                }
            }
        }
        self.rows_seen += rows;
    }

    /// Final per-lane ranges.
    pub fn lane_ranges(&self) -> (Vec<f32>, Vec<f32>) {
        let fix = |v: &Vec<f32>| {
            v.iter()
                .map(|&x| if x.is_finite() { x } else { 0.0 })
                .collect::<Vec<_>>()
        };
        (fix(&self.lo), fix(&self.hi))
    }

    /// Reduce to a single (lo, hi) per-tensor range; for the MSE estimator
    /// this runs the clipping-grid search of Choukroun et al. (2019) /
    /// Banner et al. (2018).
    pub fn tensor_range(&self, grid: QGrid) -> (f32, f32) {
        self.tensor_range_pool(grid, Pool::global())
    }

    /// Pool-explicit [`RangeTracker::tensor_range`] (the MSE grid search
    /// fans its candidate ranges across workers).
    pub fn tensor_range_pool(&self, grid: QGrid, pool: &Pool) -> (f32, f32) {
        let (lo, hi) = self.lane_ranges();
        let lo = lo.iter().copied().fold(f32::INFINITY, f32::min).min(0.0);
        let hi = hi.iter().copied().fold(f32::NEG_INFINITY, f32::max).max(0.0);
        match self.kind {
            Estimator::Mse => mse_search_pool(&self.reservoir, lo, hi, grid, pool),
            _ => (lo, hi),
        }
    }
}

/// Grid search over symmetric shrinkage of [lo, hi] minimising the
/// quantize-dequantize MSE on `samples`.
pub fn mse_search(samples: &[f32], lo: f32, hi: f32, grid: QGrid) -> (f32, f32) {
    mse_search_pool(samples, lo, hi, grid, Pool::global())
}

/// Pool-explicit [`mse_search`]: each of the 41 candidate ranges scores on
/// its own worker, streaming the QDQ error in sample order without
/// materialising a buffer (same per-element ops and summation order as
/// `qdq_slice` + a sum pass, so numerically identical to the serial
/// reference); the argmin scans candidates in step order with a strict
/// `<`, exactly like the serial loop — the chosen range is bit-identical
/// for any worker count.
pub fn mse_search_pool(
    samples: &[f32],
    lo: f32,
    hi: f32,
    grid: QGrid,
    pool: &Pool,
) -> (f32, f32) {
    if samples.is_empty() || hi <= lo {
        // Degenerate ranges happen for real: a constant-valued site gives
        // lo == hi != 0. Returning them untouched would hand downstream a
        // zero-width range, so clamp to the smallest valid range that
        // contains both the observed value and 0 (0 must stay exactly
        // representable — padding, ReLU sparsity).
        return (lo.min(0.0), hi.max(0.0));
    }
    let score_step = |step: usize| {
        let alpha = 1.0 - 0.02 * step as f32; // 1.00, 0.98 .. 0.20
        let clo = lo * alpha;
        let chi = hi * alpha;
        let p = qparams_from_range(clo, chi, grid);
        let inv = 1.0 / p.scale;
        let mut err = 0.0f32;
        for &x in samples {
            let y = qdq_one(x, inv, p, grid);
            err += (x - y) * (x - y);
        }
        (err, clo, chi)
    };
    let scored: Vec<(f32, f32, f32)> =
        if pool.threads() <= 1 || samples.len() < MSE_PAR_MIN_SAMPLES {
            (0..=40).map(score_step).collect()
        } else {
            let steps: Vec<usize> = (0..=40).collect();
            pool.par_map(&steps, |_, &step| score_step(step))
        };
    let mut best = (lo, hi);
    let mut best_err = f32::INFINITY;
    for (err, clo, chi) in scored {
        if err < best_err {
            best_err = err;
            best = (clo, chi);
        }
    }
    best
}

/// Per-group MSE grid search over retained row samples (`rows` is the
/// row-major `(n, lanes)` buffer of [`RangeTracker::row_samples`]; the
/// row count derives from the buffer length, so a mismatched count can
/// never index out of bounds): for each lane group, gather the group's
/// values (rows outer, members in group order inner), seed the search
/// with the group's tracked range from `lo`/`hi`, and run the
/// 41-candidate grid search.
///
/// Groups fan out one-per-pool-job with *serial* inner scoring, and every
/// group's sample gather and argmin are order-fixed — so the chosen
/// ranges are bit-identical for any worker count, like
/// [`mse_search_pool`].
pub fn mse_search_groups_pool(
    rows: &[f32],
    lanes: usize,
    groups: &[Vec<usize>],
    lo: &[f32],
    hi: &[f32],
    grid: QGrid,
    pool: &Pool,
) -> Vec<(f32, f32)> {
    let n_rows = if lanes == 0 { 0 } else { rows.len() / lanes };
    let serial = Pool::serial();
    let search_one = |members: &Vec<usize>, inner: &Pool| -> (f32, f32) {
        let glo = members.iter().map(|&j| lo[j]).fold(f32::INFINITY, f32::min);
        let ghi = members.iter().map(|&j| hi[j]).fold(f32::NEG_INFINITY, f32::max);
        let mut samples = Vec::with_capacity(n_rows * members.len());
        for r in 0..n_rows {
            let row = &rows[r * lanes..(r + 1) * lanes];
            for &j in members {
                samples.push(row[j]);
            }
        }
        mse_search_pool(&samples, glo, ghi, grid, inner)
    };
    if groups.len() == 1 {
        // a single group (per-tensor-granularity site) has no group-level
        // parallelism to spend the pool on — hand it to the candidate
        // scan instead; mse_search_pool is bit-identical at any worker
        // count, so the chosen range is unchanged
        return vec![search_one(&groups[0], pool)];
    }
    if pool.threads() <= 1 {
        groups.iter().map(|g| search_one(g, &serial)).collect()
    } else {
        pool.par_map(groups, |_, g| search_one(g, &serial))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{qdq_slice, qdq_tensor, qparams_from_range};
    use crate::util::prop::{prop_check, prop_assert};
    use crate::util::rng::Rng;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::new(shape.to_vec(), data).unwrap()
    }

    #[test]
    fn current_minmax_tracks_last_batch() {
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, 2);
        tr.observe(&t(&[2, 2], vec![-5., 1., 2., 3.])).unwrap();
        tr.observe(&t(&[2, 2], vec![-1., 0., 1., 2.])).unwrap();
        let (lo, hi) = tr.lane_ranges();
        assert_eq!((lo[0], hi[0]), (-1., 1.));
        assert_eq!((lo[1], hi[1]), (0., 2.));
    }

    #[test]
    fn running_minmax_is_ema() {
        let mut tr = RangeTracker::new(Estimator::RunningMinMax, 1);
        tr.observe(&t(&[2], vec![0.0, 10.0])).unwrap();
        tr.observe(&t(&[2], vec![0.0, 20.0])).unwrap();
        let (_, hi) = tr.lane_ranges();
        let expected = 0.9 * 10.0 + 0.1 * 20.0;
        assert!((hi[0] - expected).abs() < 1e-5, "{} vs {expected}", hi[0]);
    }

    #[test]
    fn mse_estimator_clips_outliers() {
        // at 4 bits, one huge outlier among thousands of small values makes
        // the full min-max range catastrophic; the MSE search must clip.
        // (At 8 bits keeping the outlier can genuinely be optimal — the
        // trade-off the paper's §3 range-vs-precision discussion describes.)
        let mut rng = Rng::new(1);
        let mut data: Vec<f32> = (0..4096).map(|_| rng.uniform(0.0, 1.0)).collect();
        data[7] = 10.0;
        let mut tr = RangeTracker::new(Estimator::Mse, 1);
        tr.observe(&t(&[4096], data)).unwrap();
        let (_lo, hi) = tr.tensor_range(QGrid::asymmetric(4));
        assert!(hi < 5.0, "hi {hi} not clipped");
    }

    #[test]
    fn mse_beats_minmax_on_outlier_data() {
        let mut rng = Rng::new(2);
        let mut data: Vec<f32> = (0..8192).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        data[0] = 80.0;
        let tensor = t(&[8192], data.clone());
        let grid = QGrid::asymmetric(8);

        let mut mm = RangeTracker::new(Estimator::CurrentMinMax, 1);
        mm.observe(&tensor).unwrap();
        let (l1, h1) = mm.tensor_range(grid);
        let e_mm = qdq_tensor(&tensor, qparams_from_range(l1, h1, grid), grid)
            .mse(&tensor)
            .unwrap();

        let mut ms = RangeTracker::new(Estimator::Mse, 1);
        ms.observe(&tensor).unwrap();
        let (l2, h2) = ms.tensor_range(grid);
        let e_ms = qdq_tensor(&tensor, qparams_from_range(l2, h2, grid), grid)
            .mse(&tensor)
            .unwrap();

        assert!(e_ms < e_mm, "mse {e_ms} !< minmax {e_mm}");
    }

    #[test]
    fn scalar_lane_tracker_accepts_any_shape() {
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, 1);
        tr.observe(&t(&[2, 3, 4], (0..24).map(|i| i as f32).collect())).unwrap();
        let (lo, hi) = tr.lane_ranges();
        assert_eq!((lo[0], hi[0]), (0.0, 23.0));
    }

    #[test]
    fn prop_running_bounded_by_extremes() {
        prop_check("running in hull", 100, |rng| {
            let mut tr = RangeTracker::new(Estimator::RunningMinMax, 1);
            let mut gmin = f32::INFINITY;
            let mut gmax = f32::NEG_INFINITY;
            for _ in 0..5 {
                let data: Vec<f32> = (0..32).map(|_| rng.uniform(-9.0, 9.0)).collect();
                gmin = gmin.min(data.iter().copied().fold(f32::INFINITY, f32::min));
                gmax = gmax.max(data.iter().copied().fold(f32::NEG_INFINITY, f32::max));
                tr.observe(&t(&[32], data)).unwrap();
            }
            let (lo, hi) = tr.lane_ranges();
            prop_assert(
                lo[0] >= gmin - 1e-5 && hi[0] <= gmax + 1e-5,
                format!("EMA range [{},{}] outside hull [{gmin},{gmax}]", lo[0], hi[0]),
            )
        });
    }

    #[test]
    fn late_batch_outlier_influences_chosen_range() {
        // Three batches totalling 2x the reservoir capacity + a tail. The
        // outlier arrives in the LAST batch, after the reservoir has
        // filled and re-thinned twice — the old fill-once reservoir never
        // saw it, and the grid search clipped the range to alpha_min *
        // 50 = 10. With stride re-thinning the outlier is sampled, and
        // keeping (most of) the full range is MSE-optimal.
        let cap = 1 << 16;
        let mut rng = Rng::new(9);
        let mut tr = RangeTracker::new(Estimator::Mse, 1);
        for _ in 0..2 {
            let data: Vec<f32> = (0..cap).map(|_| rng.uniform(0.0, 1.0)).collect();
            tr.observe(&t(&[cap], data)).unwrap();
        }
        let mut tail: Vec<f32> = (0..1000).map(|_| rng.uniform(0.0, 1.0)).collect();
        tail[0] = 50.0;
        tr.observe(&t(&[1000], tail)).unwrap();

        // the reservoir stayed bounded and kept sampling the whole stream
        assert!(tr.reservoir.len() <= cap);
        assert_eq!(tr.stride, 4);
        assert_eq!(tr.seen, 2 * cap + 1000);
        assert!(tr.reservoir.contains(&50.0), "late outlier not sampled");

        let (_, hi) = tr.tensor_range(QGrid::asymmetric(8));
        assert!(hi > 25.0, "late-batch outlier ignored: chosen hi = {hi}");
    }

    #[test]
    fn row_samples_are_opt_in_and_lane_aligned() {
        let mut tr = RangeTracker::new(Estimator::RunningMinMax, 3);
        tr.observe(&t(&[2, 3], vec![1., 2., 3., 4., 5., 6.])).unwrap();
        assert!(!tr.has_row_samples());
        assert!(tr.row_samples().is_none());

        let mut tr = RangeTracker::new(Estimator::RunningMinMax, 3).with_row_samples();
        tr.observe(&t(&[2, 3], vec![1., 2., 3., 4., 5., 6.])).unwrap();
        tr.observe(&t(&[1, 3], vec![7., 8., 9.])).unwrap();
        let (rows, n) = tr.row_samples().unwrap();
        assert_eq!(n, 3);
        // lane j of every retained row is an actual lane-j value
        assert_eq!(rows, &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
    }

    #[test]
    fn row_reservoir_stays_bounded_and_strided() {
        let cap = 1 << 11;
        let d = 4;
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, d).with_row_samples();
        // 3x capacity in rows; values encode their global row index
        for b in 0..3 {
            let tensor = Tensor::from_fn(&[cap, d], |i| (b * cap + i / d) as f32);
            tr.observe(&tensor).unwrap();
        }
        let (rows, n) = tr.row_samples().unwrap();
        assert!(n <= cap, "reservoir overflow: {n}");
        assert_eq!(rows.len(), n * d);
        // invariant: retained row i is stream row i * stride
        assert_eq!(tr.row_stride, 4);
        for i in 0..n {
            assert_eq!(rows[i * d], (i * tr.row_stride) as f32, "row {i}");
        }
        // late rows are represented
        assert!(rows[(n - 1) * d] >= (2 * cap) as f32);
    }

    #[test]
    fn scalar_tracker_rows_are_elements() {
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, 1).with_row_samples();
        tr.observe(&t(&[2, 3], vec![1., 2., 3., 4., 5., 6.])).unwrap();
        let (rows, n) = tr.row_samples().unwrap();
        assert_eq!(n, 6);
        assert_eq!(rows, &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn group_search_isolates_outlier_group() {
        // lanes 0/1 tight, lanes 2/3 heavy-tailed around one huge value:
        // per-group search at 4 bits clips the outlier group's range but
        // leaves the tight group's intact
        let mut rng = Rng::new(4);
        let d = 4;
        let n_rows = 2048;
        let mut tr = RangeTracker::new(Estimator::CurrentMinMax, d).with_row_samples();
        let tensor = Tensor::from_fn(&[n_rows, d], |i| {
            let lane = i % d;
            if lane < 2 {
                rng.uniform(-1.0, 1.0)
            } else {
                rng.normal_f32(0.0, 1.0)
            }
        });
        tr.observe(&tensor).unwrap();
        let (lo, mut hi) = tr.lane_ranges();
        // install an outlier the search should clip away at 4 bits
        hi[3] = 60.0;
        let (rows, _) = tr.row_samples().unwrap();
        let groups = vec![vec![0usize, 1], vec![2usize, 3]];
        let ranges = mse_search_groups_pool(
            rows,
            d,
            &groups,
            &lo,
            &hi,
            QGrid::asymmetric(4),
            &Pool::serial(),
        );
        assert_eq!(ranges.len(), 2);
        // tight group keeps (most of) its range
        assert!(ranges[0].1 > 0.5, "tight group clipped to {:?}", ranges[0]);
        // outlier group is clipped well below the installed 60.0
        assert!(ranges[1].1 < 30.0, "outlier group kept {:?}", ranges[1]);
    }

    #[test]
    fn prop_degenerate_constant_range_clamps_to_include_zero() {
        prop_check("constant site range", 100, |rng| {
            let c = rng.uniform(-10.0, 10.0);
            let samples = vec![c; 33];
            let (lo, hi) = mse_search(&samples, c, c, QGrid::asymmetric(8));
            prop_assert(
                lo == c.min(0.0) && hi == c.max(0.0) && lo <= 0.0 && hi >= 0.0,
                format!("constant {c}: got [{lo}, {hi}]"),
            )
        });
    }

    #[test]
    fn mse_search_never_worse_than_full_range() {
        prop_check("mse <= minmax", 50, |rng| {
            let n = 2048;
            let data: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let lo = data.iter().copied().fold(f32::INFINITY, f32::min).min(0.0);
            let hi = data.iter().copied().fold(f32::NEG_INFINITY, f32::max).max(0.0);
            let grid = QGrid::asymmetric(4);
            let (slo, shi) = mse_search(&data, lo, hi, grid);
            let err = |l: f32, h: f32| {
                let mut buf = data.clone();
                qdq_slice(&mut buf, qparams_from_range(l, h, grid), grid);
                data.iter().zip(&buf).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
            };
            prop_assert(
                err(slo, shi) <= err(lo, hi) + 1e-4,
                format!("search worse: {} > {}", err(slo, shi), err(lo, hi)),
            )
        });
    }
}
