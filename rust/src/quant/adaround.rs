//! AdaRound (Nagel et al., 2020): learned rounding for post-training weight
//! quantization — used by the paper for W4 PTQ (Table 7: W4A32 AdaRound
//! recovers 81.46 GLUE vs 72.31 for nearest rounding).
//!
//! Per linear layer, we optimise a continuous variable V (same shape as W)
//! through the rectified sigmoid h(V) = clip(sigmoid(V)(ζ-γ)+γ, 0, 1) so
//! the quantized weight becomes
//!     W~ = s * clip(floor(W/s) + h(V), qmin, qmax)
//! minimising the layer reconstruction loss
//!     L = ||X W - X W~||_F^2 + λ Σ (1 - |2 h(V) - 1|^β)
//! where X holds calibration inputs for the layer. Gradients are analytic
//! (the loss is quadratic in W~): dL/dW~ = 2 G (W~ - W) with G = XᵀX
//! precomputed once, so each iteration is two (d×d)·(d×out) matmuls.
//! Default hyper-parameters follow the paper: λ anneals β from 20 → 2,
//! Adam on V, ~10^4 iterations (configurable; our layers are small).

use anyhow::{bail, Result};

use super::{QGrid, QParams};
use crate::tensor::Tensor;
use crate::util::pool::Pool;

const ZETA: f32 = 1.1;
const GAMMA: f32 = -0.1;

/// Below this V size the per-element Adam update stays serial (the matmul
/// still parallelises via its own threshold).
const PAR_MIN_LANES: usize = 1 << 12;

#[derive(Debug, Clone)]
pub struct AdaRoundCfg {
    pub iters: usize,
    pub lr: f32,
    /// rounding-regulariser weight
    pub lambda: f32,
    /// β annealing range (paper: 20 -> 2 over the last 2/3 of training)
    pub beta_start: f32,
    pub beta_end: f32,
}

impl Default for AdaRoundCfg {
    fn default() -> Self {
        // tuned on this substrate (see EXPERIMENTS.md): AdaRound's win
        // comes from cross-element coupling in G = XᵀX, so the gains are
        // largest for correlated activations; λ=0.1 balances the
        // regulariser against our layers' recon-gradient scale.
        AdaRoundCfg { iters: 1500, lr: 3e-2, lambda: 0.1, beta_start: 20.0, beta_end: 2.0 }
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn h(v: f32) -> f32 {
    (sigmoid(v) * (ZETA - GAMMA) + GAMMA).clamp(0.0, 1.0)
}

/// d h / d v (zero in the clipped regions).
fn dh(v: f32) -> f32 {
    let s = sigmoid(v);
    let raw = s * (ZETA - GAMMA) + GAMMA;
    if (0.0..=1.0).contains(&raw) {
        s * (1.0 - s) * (ZETA - GAMMA)
    } else {
        0.0
    }
}

/// Result of the optimisation.
pub struct AdaRoundResult {
    /// quantize-dequantized weight with learned rounding
    pub weight: Tensor,
    pub initial_loss: f32,
    pub final_loss: f32,
}

/// Optimise rounding of `w` (in_dim, out_dim) given calibration inputs
/// `x` (n, in_dim) and per-tensor symmetric parameters `p`.
pub fn adaround(
    w: &Tensor,
    x: &Tensor,
    p: QParams,
    grid: QGrid,
    cfg: &AdaRoundCfg,
) -> Result<AdaRoundResult> {
    if x.shape().len() != 2 {
        bail!("adaround wants 2-D x");
    }
    let g = x.transpose2()?.matmul(x)?; // (din, din), XᵀX
    adaround_with_gram(w, &g, x.shape()[0].max(1) as f32, p, grid, cfg)
}

/// Same as [`adaround`], but with the Gram matrix G = XᵀX precomputed —
/// the calibration pipeline accumulates G incrementally over batches so
/// full activation matrices never need to be held in memory.
pub fn adaround_with_gram(
    w: &Tensor,
    g: &Tensor,
    n: f32,
    p: QParams,
    grid: QGrid,
    cfg: &AdaRoundCfg,
) -> Result<AdaRoundResult> {
    adaround_with_gram_pool(w, g, n, p, grid, cfg, Pool::global())
}

/// Per-element Adam state for one V entry (struct-of-arrays would split
/// poorly across the pool; one array of lanes partitions cleanly).
#[derive(Clone, Copy)]
struct Lane {
    v: f32,
    m: f32,
    s2: f32,
}

/// Pool-explicit [`adaround_with_gram`]. The two per-iteration hot spots —
/// the (din,din)x(din,dout) Gram matmul and the elementwise Adam update on
/// V — fan out across workers; both are computed in the same per-element
/// order as the serial kernel, so the optimisation trajectory is
/// bit-identical for any worker count.
pub fn adaround_with_gram_pool(
    w: &Tensor,
    g: &Tensor,
    n: f32,
    p: QParams,
    grid: QGrid,
    cfg: &AdaRoundCfg,
    pool: &Pool,
) -> Result<AdaRoundResult> {
    if w.shape().len() != 2 || g.shape().len() != 2 {
        bail!("adaround wants 2-D w and g");
    }
    let (din, dout) = (w.shape()[0], w.shape()[1]);
    if g.shape() != [din, din] {
        bail!("gram shape {:?} != [{din}, {din}]", g.shape());
    }
    let n = n.max(1.0);

    // floor grid & reference product
    let wfloor: Vec<f32> = w.data().iter().map(|&v| (v / p.scale).floor()).collect();

    // V init so that h(V) reproduces nearest rounding bias (paper init):
    // rest = W/s - floor(W/s);  h(v0) = rest  =>  v0 = -ln((ζ-γ)/(rest-γ) - 1)
    let v0: Vec<f32> = w
        .data()
        .iter()
        .zip(&wfloor)
        .map(|(&wv, &fl)| {
            let rest = (wv / p.scale - fl).clamp(0.01, 0.99);
            -(((ZETA - GAMMA) / (rest - GAMMA) - 1.0).max(1e-6)).ln()
        })
        .collect();

    let quantized = |v: &[f32]| -> Tensor {
        let data: Vec<f32> = wfloor
            .iter()
            .zip(v)
            .map(|(&fl, &vv)| p.scale * (fl + h(vv)).clamp(grid.qmin, grid.qmax))
            .collect();
        Tensor::new(vec![din, dout], data).unwrap()
    };

    let recon_loss = |wq: &Tensor| -> f32 {
        // ||X (Wq - W)||^2 / n  computed as tr(Δᵀ G Δ) / n
        let delta = wq.sub(w).unwrap();
        let gd = g.matmul_pool(&delta, pool).unwrap();
        delta
            .data()
            .iter()
            .zip(gd.data())
            .map(|(a, b)| a * b)
            .sum::<f32>()
            / n
    };

    // reference point: HARD nearest rounding (what AdaRound must beat).
    // The soft-init loss is ~0 by construction (h(v0) == the fractional
    // rest, so W~ == W), which is not a meaningful baseline.
    let hard = |v: &[f32]| -> Tensor {
        let data: Vec<f32> = wfloor
            .iter()
            .zip(v)
            .map(|(&fl, &vv)| {
                let hv = if h(vv) >= 0.5 { 1.0 } else { 0.0 };
                p.scale * (fl + hv).clamp(grid.qmin, grid.qmax)
            })
            .collect();
        Tensor::new(vec![din, dout], data).unwrap()
    };
    let initial_loss = recon_loss(&hard(&v0));

    // Adam state on V
    let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
    let mut state: Vec<Lane> =
        v0.into_iter().map(|v| Lane { v, m: 0.0, s2: 0.0 }).collect();
    let mut vs = vec![0.0f32; state.len()];

    for it in 0..cfg.iters {
        for (dst, l) in vs.iter_mut().zip(&state) {
            *dst = l.v;
        }
        let wq = quantized(&vs);
        let delta = wq.sub(w)?;
        // dL/dWq = 2 G Δ / n
        let gd = g.matmul_pool(&delta, pool)?;
        let frac = it as f32 / cfg.iters.max(1) as f32;
        let beta = cfg.beta_end + (cfg.beta_start - cfg.beta_end) * (1.0 - frac);
        let warm = frac > 0.2; // no regulariser during warmup (paper)

        let update = |base: usize, block: &mut [Lane]| {
            for (j, lane) in block.iter_mut().enumerate() {
                let i = base + j;
                // chain rule through clip(floor + h(V)): zero if clipped
                let q_unclipped = wfloor[i] + h(lane.v);
                let dq = if (grid.qmin..=grid.qmax).contains(&q_unclipped) {
                    p.scale * dh(lane.v)
                } else {
                    0.0
                };
                let mut grad = 2.0 * gd.data()[i] / n * dq;
                if warm {
                    // d/dv [λ (1 - |2h-1|^β)]
                    let hv = h(lane.v);
                    let t = 2.0 * hv - 1.0;
                    let a = t.abs().max(1e-6);
                    grad += cfg.lambda
                        * (-beta * a.powf(beta - 1.0) * t.signum() * 2.0 * dh(lane.v));
                }
                lane.m = b1 * lane.m + (1.0 - b1) * grad;
                lane.s2 = b2 * lane.s2 + (1.0 - b2) * grad * grad;
                lane.v -= cfg.lr * lane.m / (lane.s2.sqrt() + eps);
            }
        };
        if pool.threads() <= 1 || state.len() < PAR_MIN_LANES {
            update(0, &mut state);
        } else {
            let chunk = state.len().div_ceil(pool.threads()).max(1);
            pool.par_chunks_mut(&mut state, chunk, |ci, block| update(ci * chunk, block));
        }
    }

    // snap to hard rounding (h in {0,1}) for deployment
    for (dst, l) in vs.iter_mut().zip(&state) {
        *dst = l.v;
    }
    let weight = hard(&vs);
    let final_loss = recon_loss(&weight);
    Ok(AdaRoundResult { weight, initial_loss, final_loss })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{qdq_tensor, qparams_symmetric};
    use crate::util::rng::Rng;

    fn setup(din: usize, dout: usize, n: usize, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[din, dout], 0.5, &mut rng);
        // correlated activations (x = z @ mix): the regime where learned
        // rounding beats nearest — with white inputs G = XᵀX is ~diagonal
        // and nearest rounding is already near-optimal.
        let z = Tensor::randn(&[n, din], 1.0, &mut rng);
        let mix = Tensor::randn(&[din, din], (1.0 / din as f32).sqrt(), &mut rng);
        let x = z.matmul(&mix).unwrap();
        (w, x)
    }

    #[test]
    fn h_is_rectified_sigmoid() {
        assert_eq!(h(-100.0), 0.0);
        assert_eq!(h(100.0), 1.0);
        assert!(h(0.0) > 0.0 && h(0.0) < 1.0);
        // derivative zero in clipped regions, positive inside
        assert_eq!(dh(-100.0), 0.0);
        assert!(dh(0.0) > 0.0);
    }

    #[test]
    fn improves_over_nearest_rounding_at_low_bits() {
        // 3-bit weights: learned rounding must beat round-to-nearest on the
        // layer reconstruction loss (the paper's Table 7 mechanism)
        let (w, x) = setup(16, 8, 128, 3);
        let grid = QGrid::symmetric(3);
        let p = qparams_symmetric(w.abs_max(), grid);

        let nearest = qdq_tensor(&w, p, grid);
        let xe = |wq: &Tensor| {
            x.matmul(wq).unwrap().mse(&x.matmul(&w).unwrap()).unwrap()
        };
        let res = adaround(&w, &x, p, grid, &AdaRoundCfg { iters: 600, ..Default::default() })
            .unwrap();
        let e_near = xe(&nearest);
        let e_ada = xe(&res.weight);
        assert!(
            e_ada < e_near * 0.7,
            "adaround {e_ada} vs nearest {e_near}"
        );
    }

    #[test]
    fn output_stays_on_quant_grid() {
        let (w, x) = setup(8, 4, 32, 5);
        let grid = QGrid::symmetric(4);
        let p = qparams_symmetric(w.abs_max(), grid);
        let res = adaround(&w, &x, p, grid, &AdaRoundCfg { iters: 100, ..Default::default() })
            .unwrap();
        for &v in res.weight.data() {
            let q = v / p.scale;
            assert!((q - q.round()).abs() < 1e-4, "off grid: {v}");
            assert!(q.round() >= grid.qmin && q.round() <= grid.qmax);
        }
    }

    #[test]
    fn rounding_moves_at_most_one_step() {
        // AdaRound only chooses floor vs ceil — |W~ - W| < scale always
        let (w, x) = setup(8, 8, 64, 7);
        let grid = QGrid::symmetric(4);
        let p = qparams_symmetric(w.abs_max(), grid);
        let res = adaround(&w, &x, p, grid, &AdaRoundCfg { iters: 200, ..Default::default() })
            .unwrap();
        for (a, b) in w.data().iter().zip(res.weight.data()) {
            assert!((a - b).abs() <= p.scale + 1e-5, "moved {} -> {}", a, b);
        }
    }

    #[test]
    fn final_loss_not_worse_than_initial() {
        let (w, x) = setup(12, 6, 96, 9);
        let grid = QGrid::symmetric(3);
        let p = qparams_symmetric(w.abs_max(), grid);
        let res = adaround(&w, &x, p, grid, &AdaRoundCfg { iters: 500, ..Default::default() })
            .unwrap();
        assert!(res.final_loss <= res.initial_loss * 1.05,
                "{} vs {}", res.final_loss, res.initial_loss);
    }
}
