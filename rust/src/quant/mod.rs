//! Quantization library: uniform affine quantizers (paper Eq. 1-2), range
//! -> parameter conversion, granularity machinery, and the simulated
//! quantize-dequantize used for weight PTQ and estimator search.
//!
//! Activation quantization is *executed* inside the HLO graphs (L1 Pallas
//! kernel); this module computes the scale / zero-point / config tensors
//! that parameterise those graphs, and performs weight QDQ on the
//! parameter tensors before they are fed to the runtime (exactly the
//! paper's simulation setup, Jacob et al. 2018).

pub mod adaround;
pub mod estimators;
pub mod peg;

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::pool::Pool;

/// Minimum element count before the pooled QDQ kernels go parallel (the
/// parallel kernels are bit-identical to serial; this only bounds spawn
/// overhead).
const PAR_MIN_ELEMS: usize = 1 << 15;

/// Quantization grid for `bits`, asymmetric (unsigned) or symmetric
/// (signed) — the paper uses asymmetric activations + symmetric weights.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QGrid {
    pub qmin: f32,
    pub qmax: f32,
}

impl QGrid {
    pub fn asymmetric(bits: u32) -> QGrid {
        QGrid { qmin: 0.0, qmax: (2f64.powi(bits as i32) - 1.0) as f32 }
    }

    pub fn symmetric(bits: u32) -> QGrid {
        let half = 2f64.powi(bits as i32 - 1);
        QGrid { qmin: (-half + 1.0) as f32, qmax: (half - 1.0) as f32 }
    }

    pub fn levels(&self) -> f32 {
        self.qmax - self.qmin
    }
}

/// Scale + zero-point for one quantizer lane (or a whole tensor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    pub scale: f32,
    pub zero_point: f32,
}

/// Derive affine parameters from an observed [lo, hi] range.
///
/// The range is first widened to include zero (required so that real zeros
/// — padding, ReLU-style sparsity — are exactly representable, as in
/// Krishnamoorthi 2018 §3).
pub fn qparams_from_range(lo: f32, hi: f32, grid: QGrid) -> QParams {
    let lo = lo.min(0.0);
    let hi = hi.max(0.0);
    let range = (hi - lo).max(1e-8);
    let scale = range / grid.levels();
    let zero_point = (grid.qmin - lo / scale).round().clamp(grid.qmin, grid.qmax);
    QParams { scale, zero_point }
}

/// Symmetric parameters from the absolute max.
pub fn qparams_symmetric(abs_max: f32, grid: QGrid) -> QParams {
    let scale = (abs_max.max(1e-8)) / grid.qmax;
    QParams { scale, zero_point: 0.0 }
}

/// Quantize-dequantize one value (paper Eq. 1-2).
#[inline]
pub fn qdq(x: f32, p: QParams, grid: QGrid) -> f32 {
    let q = (x / p.scale).round() + p.zero_point;
    let q = q.clamp(grid.qmin, grid.qmax);
    p.scale * (q - p.zero_point)
}

/// The per-element QDQ op shared by [`qdq_slice`] and the MSE range
/// search — one definition so the search scores candidates with exactly
/// the quantizer it is searching for. `inv` must be `1.0 / p.scale`.
#[inline]
pub fn qdq_one(x: f32, inv: f32, p: QParams, grid: QGrid) -> f32 {
    let q = (x * inv).round() + p.zero_point;
    p.scale * (q.clamp(grid.qmin, grid.qmax) - p.zero_point)
}

/// Quantize-dequantize a whole slice with per-tensor parameters (serial
/// reference kernel; [`qdq_slice_pool`] is the parallel entry point).
pub fn qdq_slice(xs: &mut [f32], p: QParams, grid: QGrid) {
    let inv = 1.0 / p.scale;
    for x in xs {
        *x = qdq_one(*x, inv, p, grid);
    }
}

/// Pool-parallel [`qdq_slice`]: elementwise, so any chunking is
/// bit-identical to the serial kernel.
pub fn qdq_slice_pool(xs: &mut [f32], p: QParams, grid: QGrid, pool: &Pool) {
    if pool.threads() <= 1 || xs.len() < PAR_MIN_ELEMS {
        qdq_slice(xs, p, grid);
        return;
    }
    let per = xs.len().div_ceil(pool.threads()).max(1);
    pool.par_chunks_mut(xs, per, |_, chunk| qdq_slice(chunk, p, grid));
}

/// Quantize-dequantize a tensor per-tensor; returns a new tensor.
pub fn qdq_tensor(t: &Tensor, p: QParams, grid: QGrid) -> Tensor {
    qdq_tensor_pool(t, p, grid, Pool::global())
}

/// Pool-explicit [`qdq_tensor`].
pub fn qdq_tensor_pool(t: &Tensor, p: QParams, grid: QGrid, pool: &Pool) -> Tensor {
    let mut out = t.clone();
    qdq_slice_pool(out.data_mut(), p, grid, pool);
    out
}

/// Per-lane (last axis) quantize-dequantize with a scale/zp vector.
pub fn qdq_per_lane(t: &Tensor, params: &[QParams], grid: QGrid) -> Result<Tensor> {
    qdq_per_lane_pool(t, params, grid, Pool::global())
}

/// Pool-explicit [`qdq_per_lane`]: rows are partitioned across workers on
/// row-aligned boundaries; per-element math is unchanged, so results are
/// bit-identical for any worker count.
pub fn qdq_per_lane_pool(
    t: &Tensor,
    params: &[QParams],
    grid: QGrid,
    pool: &Pool,
) -> Result<Tensor> {
    let d = t.last_dim();
    if params.len() != d {
        bail!("params len {} != lane count {}", params.len(), d);
    }
    let mut out = t.clone();
    let rows = t.rows();
    let qdq_rows = |block: &mut [f32]| {
        for row in block.chunks_exact_mut(d) {
            for (x, p) in row.iter_mut().zip(params) {
                let q = (*x / p.scale).round() + p.zero_point;
                *x = p.scale * (q.clamp(grid.qmin, grid.qmax) - p.zero_point);
            }
        }
    };
    if pool.threads() <= 1 || t.len() < PAR_MIN_ELEMS || d == 0 {
        qdq_rows(out.data_mut());
    } else {
        let rows_per = rows.div_ceil(pool.threads()).max(1);
        pool.par_chunks_mut(out.data_mut(), rows_per * d, |_, block| qdq_rows(block));
    }
    Ok(out)
}

/// Per-channel symmetric weight QDQ: one scale per output channel
/// (column of a (in, out) matrix), optionally in channel groups — the
/// Q-BERT-style group-wise baseline the paper compares against (Table 6
/// footnote ψ).
pub fn qdq_weight_per_channel(w: &Tensor, bits: u32, groups: usize) -> Result<Tensor> {
    qdq_weight_per_channel_pool(w, bits, groups, Pool::global())
}

/// Pool-explicit [`qdq_weight_per_channel`]: group absolute maxima are
/// found in parallel (one read-only scan per group, same scan order as the
/// serial kernel), then rows quantize in parallel with the per-group
/// parameters — bit-identical for any worker count.
pub fn qdq_weight_per_channel_pool(
    w: &Tensor,
    bits: u32,
    groups: usize,
    pool: &Pool,
) -> Result<Tensor> {
    if w.shape().len() != 2 {
        bail!("per-channel weight QDQ wants 2-D, got {:?}", w.shape());
    }
    let grid = QGrid::symmetric(bits);
    let (rows, cols) = (w.shape()[0], w.shape()[1]);
    let g = groups.clamp(1, cols);
    let gsize = cols.div_ceil(g);
    let group_params = |gi: usize| -> QParams {
        let c0 = gi * gsize;
        let c1 = ((gi + 1) * gsize).min(cols);
        if c0 >= c1 {
            return QParams { scale: 1.0, zero_point: 0.0 };
        }
        let mut amax = 0.0f32;
        for r in 0..rows {
            for c in c0..c1 {
                amax = amax.max(w.data()[r * cols + c].abs());
            }
        }
        qparams_symmetric(amax, grid)
    };
    let params: Vec<QParams> = if pool.threads() <= 1 || w.len() < PAR_MIN_ELEMS {
        (0..g).map(group_params).collect()
    } else {
        let group_ids: Vec<usize> = (0..g).collect();
        pool.par_map(&group_ids, |_, &gi| group_params(gi))
    };
    let mut out = w.clone();
    let quantize_rows = |block: &mut [f32]| {
        for row in block.chunks_exact_mut(cols) {
            for (c, x) in row.iter_mut().enumerate() {
                let p = params[c / gsize];
                let q = (*x / p.scale).round().clamp(grid.qmin, grid.qmax);
                *x = p.scale * q;
            }
        }
    };
    if pool.threads() <= 1 || w.len() < PAR_MIN_ELEMS || cols == 0 {
        quantize_rows(out.data_mut());
    } else {
        let rows_per = rows.div_ceil(pool.threads()).max(1);
        pool.par_chunks_mut(out.data_mut(), rows_per * cols, |_, block| quantize_rows(block));
    }
    Ok(out)
}

/// How ranges are estimated from calibration data (paper §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Estimator {
    /// min/max of the most recent batch
    CurrentMinMax,
    /// exponential moving average of per-batch min/max (momentum 0.9)
    RunningMinMax,
    /// grid search minimising ||x - Q(x)||^2
    Mse,
}

/// Activation-quantizer granularity (paper Fig. 3).
#[derive(Debug, Clone, PartialEq)]
pub enum Granularity {
    PerTensor,
    /// K groups over the embedding axis; `permute` = range-based
    /// permutation (paper §4 "per-embedding-group"). K need not divide
    /// the embedding dim: [`peg::group_bounds`] partitions the lanes into
    /// groups whose sizes differ by at most one.
    PerEmbeddingGroup { k: usize, permute: bool },
    PerEmbedding,
}

/// How a site's final quantization range(s) are derived from its tracked
/// calibration statistics, resolved per site at assembly time
/// ([`crate::model::qconfig::site_lane_params_pool`]).
///
/// The granularity says how lanes *share* parameters; the range method
/// says how each parameter group's range is *chosen* — tracked bounds
/// as-is, or refined by the MSE grid search (paper Appendix:
/// per-embedding MSE ranges are `MsePerGroup` + per-embedding
/// granularity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RangeMethod {
    /// Follow the calibration estimator: per-tensor sites calibrated with
    /// [`Estimator::Mse`] get the tensor grid search, everything else
    /// uses the tracked ranges — the behaviour before `range_method`
    /// existed, and the default.
    #[default]
    Auto,
    /// Tracked ranges exactly as the estimator left them, never searched.
    CurrentMinMax,
    /// Per-tensor MSE grid search over retained samples, broadcast to
    /// every lane (requires [`Granularity::PerTensor`]).
    MseTensor,
    /// One MSE grid search per granularity group, over that group's
    /// retained row samples — per-group clipped ranges on top of the PEG
    /// permutation.
    MsePerGroup,
}

impl RangeMethod {
    /// True when this method needs retained row samples
    /// ([`estimators::RangeTracker::with_row_samples`]) from calibration,
    /// given the estimator in use: `MsePerGroup` always (the per-group
    /// search needs lane-aligned values), `MseTensor` whenever the
    /// estimator is not already stashing an MSE value reservoir. The one
    /// definition both `calibrate_with` and the sweep's offline substrate
    /// consult.
    pub fn needs_row_samples(self, estimator: Estimator) -> bool {
        match self {
            RangeMethod::MsePerGroup => true,
            RangeMethod::MseTensor => estimator != Estimator::Mse,
            RangeMethod::Auto | RangeMethod::CurrentMinMax => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{prop_assert, prop_check, vec_f32};

    #[test]
    fn grid_limits() {
        assert_eq!(QGrid::asymmetric(8), QGrid { qmin: 0.0, qmax: 255.0 });
        assert_eq!(QGrid::symmetric(8), QGrid { qmin: -127.0, qmax: 127.0 });
        assert_eq!(QGrid::asymmetric(2).qmax, 3.0);
        assert_eq!(QGrid::asymmetric(16).qmax, 65535.0);
    }

    #[test]
    fn qparams_cover_range_and_zero() {
        let grid = QGrid::asymmetric(8);
        let p = qparams_from_range(-1.0, 3.0, grid);
        // zero representable exactly
        let z = qdq(0.0, p, grid);
        assert!(z.abs() < 1e-6, "zero -> {z}");
        // endpoints within half a step
        assert!((qdq(-1.0, p, grid) + 1.0).abs() <= p.scale / 2.0 + 1e-6);
        assert!((qdq(3.0, p, grid) - 3.0).abs() <= p.scale / 2.0 + 1e-6);
    }

    #[test]
    fn qdq_error_bound_property() {
        // |x - qdq(x)| <= scale/2 for x in [lo, hi] — the fundamental
        // rounding-error bound from paper Eq. 1-2.
        prop_check("qdq error bound", 300, |rng| {
            let lo = rng.uniform(-20.0, 0.0);
            let hi = rng.uniform(0.1, 20.0);
            let bits = [2u32, 4, 8, 16][rng.below(4)];
            let grid = QGrid::asymmetric(bits);
            let p = qparams_from_range(lo, hi, grid);
            let x = rng.uniform(lo.min(0.0), hi.max(0.0));
            let err = (x - qdq(x, p, grid)).abs();
            prop_assert(
                err <= p.scale / 2.0 + 1e-5,
                format!("err {err} > s/2 {} (x={x}, bits={bits})", p.scale / 2.0),
            )
        });
    }

    #[test]
    fn qdq_idempotent_property() {
        prop_check("qdq idempotent", 200, |rng| {
            let grid = QGrid::asymmetric(8);
            let p = qparams_from_range(-5.0, 5.0, grid);
            let x = rng.uniform(-8.0, 8.0); // include clipped region
            let once = qdq(x, p, grid);
            let twice = qdq(once, p, grid);
            prop_assert((once - twice).abs() < 1e-6, format!("{once} vs {twice}"))
        });
    }

    #[test]
    fn qdq_clips_outside_range() {
        let grid = QGrid::asymmetric(8);
        let p = qparams_from_range(-1.0, 1.0, grid);
        let big = qdq(100.0, p, grid);
        assert!(big <= 1.0 + p.scale, "clipped value {big}");
    }

    #[test]
    fn symmetric_weights_preserve_sign() {
        prop_check("sym sign", 200, |rng| {
            let grid = QGrid::symmetric(8);
            let amax = rng.uniform(0.1, 5.0);
            let p = qparams_symmetric(amax, grid);
            let x = rng.uniform(-amax, amax);
            let y = qdq(x, p, grid);
            prop_assert(
                x == 0.0 || y == 0.0 || x.signum() == y.signum(),
                format!("{x} -> {y}"),
            )
        });
    }

    #[test]
    fn per_lane_outlier_isolation() {
        // an outlier lane with its own scale must not degrade other lanes
        let grid = QGrid::asymmetric(8);
        let t = Tensor::new(vec![2, 3], vec![0.5, 0.4, 60.0, -0.5, 0.1, 59.0]).unwrap();
        let params = vec![
            qparams_from_range(-0.5, 0.5, grid),
            qparams_from_range(-0.5, 0.5, grid),
            qparams_from_range(0.0, 60.0, grid),
        ];
        let q = qdq_per_lane(&t, &params, grid).unwrap();
        for (a, b) in t.data().iter().zip(q.data()).take(2) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
        assert!((q.data()[2] - 60.0).abs() < 0.2);
    }

    #[test]
    fn per_channel_beats_per_tensor_on_skewed_weights() {
        // columns with very different magnitudes: per-channel wins
        let mut rngv = crate::util::rng::Rng::new(9);
        let data: Vec<f32> = (0..64 * 8)
            .map(|i| {
                let col = i % 8;
                let mag = if col == 7 { 10.0 } else { 0.1 };
                rngv.uniform(-mag, mag)
            })
            .collect();
        let w = Tensor::new(vec![64, 8], data).unwrap();
        let grid = QGrid::symmetric(4);
        let pt = qdq_tensor(&w, qparams_symmetric(w.abs_max(), grid), grid);
        let pc = qdq_weight_per_channel(&w, 4, 8).unwrap();
        // the big column quantizes identically either way; the win is on
        // the 7 small columns, which per-tensor rounds to ~zero
        let small_mse = |q: &Tensor| -> f32 {
            let mut acc = 0.0;
            let mut n = 0;
            for (i, (&a, &b)) in w.data().iter().zip(q.data()).enumerate() {
                if i % 8 != 7 {
                    acc += (a - b) * (a - b);
                    n += 1;
                }
            }
            acc / n as f32
        };
        assert!(small_mse(&pc) < small_mse(&pt) * 0.1,
                "{} vs {}", small_mse(&pc), small_mse(&pt));
    }

    #[test]
    fn low_bit_grid_small() {
        let grid = QGrid::asymmetric(2);
        let p = qparams_from_range(0.0, 3.0, grid);
        let vals: Vec<f32> = vec![0.0, 1.0, 2.0, 3.0]
            .into_iter()
            .map(|x| qdq(x, p, grid))
            .collect();
        // 2 bits = 4 levels covering [0, 3]
        assert_eq!(vals, vec![0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn prop_qdq_values_on_grid() {
        // every dequantized value must be expressible as s*(q - z), q int
        prop_check("on-grid", 200, |rng| {
            let grid = QGrid::asymmetric(4);
            let p = qparams_from_range(rng.uniform(-3.0, 0.0), rng.uniform(0.1, 3.0), grid);
            let xs = vec_f32(rng, 16, -5.0, 5.0);
            for x in xs {
                let y = qdq(x, p, grid);
                let q = y / p.scale + p.zero_point;
                prop_assert(
                    (q - q.round()).abs() < 1e-3,
                    format!("off-grid: x={x} y={y} q={q}"),
                )?;
            }
            Ok(())
        });
    }
}
