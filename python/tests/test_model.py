# pytest: L2 model semantics — shapes, quantizer plumbing, training steps.
import numpy as np

import jax
import jax.numpy as jnp

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(name="test", vocab=64, d=32, heads=2, layers=2, d_ff=64,
                    seq=16, n_out=3, outlier_dims=(5, 11))


def _quant_inputs(cfg, enable=0.0, bits=8):
    offs, S = M.site_offsets(cfg)
    n = len(M.site_spec(cfg))
    scales = jnp.full((S,), 0.05, jnp.float32)
    zps = jnp.full((S,), 128.0, jnp.float32)
    qcfg = jnp.tile(jnp.array([[0.0, float(2**bits - 1), enable]], jnp.float32),
                    (n, 1))
    return scales, zps, qcfg


def _batch(cfg, b=2, seed=0):
    rng = np.random.default_rng(seed)
    ids = rng.integers(3, cfg.vocab, (b, cfg.seq)).astype(np.int32)
    ids[:, 0] = M.CLS_ID
    ids[:, cfg.seq // 2] = M.SEP_ID
    ids[:, -1] = M.SEP_ID
    tt = np.zeros((b, cfg.seq), np.int32)
    tt[:, cfg.seq // 2:] = 1
    mask = np.ones((b, cfg.seq), np.float32)
    mask[:, -3:-1] = 0.0  # some padding in the middle-end
    return jnp.asarray(ids), jnp.asarray(tt), jnp.asarray(mask)


def _params(cfg, seed=0):
    return M.init_params(cfg, jax.random.PRNGKey(seed))


def test_spec_shapes_consistent():
    spec = M.param_spec(CFG)
    names = [n for n, _ in spec]
    assert len(names) == len(set(names))
    sites = M.site_spec(CFG)
    assert len(sites) == 2 + 13 * CFG.layers + 2
    offs, S = M.site_offsets(CFG)
    assert offs[0] == 0 and S == sum(c for _, c in sites)
    # base config mirrors the paper's proportions: 13 sites/layer
    base = M.CONFIGS["base"]
    assert len(M.site_spec(base)) == 2 + 13 * base.layers + 2


def test_forward_shapes_and_determinism():
    params = _params(CFG)
    s, z, c = _quant_inputs(CFG)
    ids, tt, mask = _batch(CFG)
    logits1, taps = M.forward(CFG, params, s, z, c, ids, tt, mask,
                              collect_taps=True, use_pallas=False)
    logits2, _ = M.forward(CFG, params, s, z, c, ids, tt, mask,
                           use_pallas=False)
    assert logits1.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(logits1), np.asarray(logits2))
    assert set(taps.keys()) == {n for n, _ in M.site_spec(CFG)}
    assert taps["layer0.res2_sum"].shape == (2, CFG.seq, CFG.d)
    assert taps["layer0.attn_probs"].shape == (2, CFG.heads, CFG.seq, CFG.seq)


def test_quant_disabled_equals_no_quant_path():
    params = _params(CFG)
    ids, tt, mask = _batch(CFG)
    s, z, c = _quant_inputs(CFG, enable=0.0)
    a, _ = M.forward(CFG, params, s, z, c, ids, tt, mask, use_pallas=False)
    s2, z2, c2 = _quant_inputs(CFG, enable=0.0, bits=2)  # bits irrelevant
    b, _ = M.forward(CFG, params, s2, z2, c2, ids, tt, mask, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_quant_enabled_perturbs_but_stays_finite():
    params = _params(CFG)
    ids, tt, mask = _batch(CFG)
    s0, z0, c0 = _quant_inputs(CFG, enable=0.0)
    fp, _ = M.forward(CFG, params, s0, z0, c0, ids, tt, mask, use_pallas=False)
    s1, z1, c1 = _quant_inputs(CFG, enable=1.0)
    q, _ = M.forward(CFG, params, s1, z1, c1, ids, tt, mask, use_pallas=False)
    assert np.all(np.isfinite(np.asarray(q)))
    assert not np.allclose(np.asarray(fp), np.asarray(q))


def test_pallas_and_jnp_paths_agree():
    params = _params(CFG)
    ids, tt, mask = _batch(CFG)
    s, z, c = _quant_inputs(CFG, enable=1.0)
    a, _ = M.forward(CFG, params, s, z, c, ids, tt, mask, use_pallas=True)
    b, _ = M.forward(CFG, params, s, z, c, ids, tt, mask, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_padding_mask_blocks_attention():
    # changing a padded token must not change the logits
    params = _params(CFG)
    ids, tt, mask = _batch(CFG)
    s, z, c = _quant_inputs(CFG)
    a, _ = M.forward(CFG, params, s, z, c, ids, tt, mask, use_pallas=False)
    ids2 = np.asarray(ids).copy()
    pad_col = CFG.seq - 2          # masked position (mask==0)
    assert mask[0, pad_col] == 0.0
    ids2[:, pad_col] = 7
    b, _ = M.forward(CFG, params, jnp.asarray(s), z, c, jnp.asarray(ids2), tt,
                     mask, use_pallas=False)
    # MASK_BIAS=-30 gives e^-30 leakage; allow tiny tolerance
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_fp32_train_step_reduces_loss():
    params = _params(CFG)
    zeros = [jnp.zeros_like(p) for p in params]
    ids, tt, mask = _batch(CFG, b=4)
    labels = jnp.asarray(np.array([0, 1, 2, 0], np.int32))
    m, v = zeros, [jnp.zeros_like(p) for p in params]
    losses = []
    for step in range(40):
        params, m, v, loss = M.fp32_train_step(
            CFG, params, m, v, ids, tt, mask, labels,
            jnp.float32(1e-3), jnp.float32(0.0), jnp.float32(0.0),
            regression=False)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.6, losses[::10]


def test_fp32_train_regression_head():
    cfg = M.ModelConfig(**{**CFG.__dict__, "n_out": 1})
    params = _params(cfg)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    ids, tt, mask = _batch(cfg, b=4)
    labels = jnp.asarray(np.array([0.1, 0.9, 0.5, 0.2], np.float32))
    losses = []
    for _ in range(25):
        params, m, v, loss = M.fp32_train_step(
            cfg, params, m, v, ids, tt, mask, labels,
            jnp.float32(5e-3), jnp.float32(0.0), jnp.float32(0.0),
            regression=True)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses[::8]


def test_outlier_aux_loss_creates_outliers():
    # after training with the aux loss, the designated FFN-output dims must
    # dominate the per-dim dynamic range at [SEP] positions — the paper's
    # Fig. 2b structure, installed per DESIGN.md §2.
    params = _params(CFG, seed=1)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    ids, tt, mask = _batch(CFG, b=4)
    labels = jnp.asarray(np.array([0, 1, 2, 0], np.int32))
    for _ in range(80):
        params, m, v, _ = M.fp32_train_step(
            CFG, params, m, v, ids, tt, mask, labels,
            jnp.float32(2e-3), jnp.float32(1.0), jnp.float32(10.0),
            regression=False)
    s, z, c = _quant_inputs(CFG)
    _, taps = M.forward(CFG, params, s, z, c, ids, tt, mask,
                        collect_taps=True, use_pallas=False)
    t = np.asarray(taps[f"layer{CFG.layers-1}.ffn_out"])  # (B,T,d)
    rng_per_dim = t.max((0, 1)) - t.min((0, 1))
    out_dims = list(CFG.outlier_dims)
    rest = [i for i in range(CFG.d) if i not in out_dims]
    # designated dims must carry large, [SEP]-structured ranges; "few dims
    # responsible" = they dwarf the typical (median) dim
    assert rng_per_dim[out_dims].min() > 8.0, rng_per_dim[out_dims]
    assert rng_per_dim[out_dims].min() > 3.0 * np.median(rng_per_dim[rest]), (
        rng_per_dim[out_dims], np.median(rng_per_dim[rest]))
    # and the FFN residual-sum range must dwarf the FFN input range
    ffn_in = np.asarray(taps[f"layer{CFG.layers-1}.ln1_out"])
    res = np.asarray(taps[f"layer{CFG.layers-1}.res2_sum"])
    assert res.max() - res.min() > 2.0 * (ffn_in.max() - ffn_in.min())


def test_qat_train_step_runs_and_updates_scales():
    cfg = CFG
    params = _params(cfg)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    offs, S = M.site_offsets(cfg)
    n = len(M.site_spec(cfg))
    n_wq = len(M.wq_spec(cfg))
    a_s = jnp.full((S,), 0.05, jnp.float32)
    a_z = jnp.full((S,), 128.0, jnp.float32)
    a_c = jnp.tile(jnp.array([[0.0, 255.0, 1.0]], jnp.float32), (n, 1))
    w_s = jnp.full((n_wq,), 0.01, jnp.float32)
    w_c = jnp.tile(jnp.array([[-127.0, 127.0, 1.0]], jnp.float32), (n_wq, 1))
    zS = jnp.zeros((S,), jnp.float32)
    zW = jnp.zeros((n_wq,), jnp.float32)
    ids, tt, mask = _batch(cfg, b=4)
    labels = jnp.asarray(np.array([0, 1, 2, 0], np.int32))

    losses = []
    ms, vs, mw, vw = zS, zS, zW, zW
    for _ in range(12):
        (params, m, v, a_s, ms, vs, w_s, mw, vw, loss) = M.qat_train_step(
            cfg, params, m, v, a_s, ms, vs, a_z, a_c,
            w_s, mw, vw, w_c, ids, tt, mask, labels,
            jnp.float32(2e-3), jnp.float32(1e-4), regression=False)
        losses.append(float(loss))
    assert np.all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses
    assert float(jnp.min(a_s)) > 0 and float(jnp.min(w_s)) > 0
    assert not np.allclose(np.asarray(a_s), 0.05)  # scales actually learned
