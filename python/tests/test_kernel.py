# pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness signal.
#
# hypothesis sweeps shapes, dtypes, scales and group counts; every kernel
# must match its ref.py oracle to float tolerance.
import numpy as np

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant, fake_quant_ste, layernorm, peg_matmul
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype, lo=-4.0, hi=4.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 97),
    d=st.sampled_from([4, 16, 64, 128]),
    bits=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
    batched=st.booleans(),
)
def test_fake_quant_matches_ref(rows, d, bits, seed, batched):
    rng = np.random.default_rng(seed)
    shape = (2, rows, d) if batched else (rows, d)
    x = _rand(rng, shape, np.float32)
    scale = jnp.asarray(rng.uniform(0.01, 0.3, size=(d,)).astype(np.float32))
    zp = jnp.asarray(rng.integers(0, 2**bits, size=(d,)).astype(np.float32))
    cfg = jnp.array([0.0, float(2**bits - 1), 1.0], jnp.float32)
    got = fake_quant(x, scale, zp, cfg)
    want = ref.fake_quant_ref(x, scale, zp, 0.0, float(2**bits - 1), 1.0)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 40), d=st.sampled_from([8, 32]), seed=st.integers(0, 2**31 - 1))
def test_fake_quant_disabled_is_identity(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (rows, d), np.float32)
    scale = jnp.full((d,), 0.1, jnp.float32)
    zp = jnp.zeros((d,), jnp.float32)
    cfg = jnp.array([0.0, 255.0, 0.0], jnp.float32)  # enable = 0
    got = fake_quant(x, scale, zp, cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_fake_quant_error_bounded_by_half_step():
    # |x - dq(x)| <= s/2 for x inside the representable range (paper Eq. 1-2)
    rng = np.random.default_rng(3)
    d = 32
    scale = jnp.full((d,), 0.05, jnp.float32)
    zp = jnp.full((d,), 128.0, jnp.float32)
    cfg = jnp.array([0.0, 255.0, 1.0], jnp.float32)
    lo, hi = float(-128 * 0.05), float(127 * 0.05)
    x = jnp.asarray(rng.uniform(lo, hi, size=(64, d)).astype(np.float32))
    dq = fake_quant(x, scale, zp, cfg)
    assert float(jnp.max(jnp.abs(x - dq))) <= 0.05 / 2 + 1e-6


def test_fake_quant_idempotent():
    # quantizing an already-quantized tensor is a no-op
    rng = np.random.default_rng(4)
    d = 16
    scale = jnp.full((d,), 0.1, jnp.float32)
    zp = jnp.full((d,), 10.0, jnp.float32)
    cfg = jnp.array([0.0, 255.0, 1.0], jnp.float32)
    x = _rand(rng, (33, d), np.float32)
    once = fake_quant(x, scale, zp, cfg)
    twice = fake_quant(once, scale, zp, cfg)
    np.testing.assert_allclose(once, twice, rtol=0, atol=1e-6)


def test_fake_quant_per_dim_scales_independent():
    # outlier dim with its own large scale must not perturb small dims
    d = 8
    x = jnp.concatenate(
        [jnp.full((5, d - 1), 0.5, jnp.float32), jnp.full((5, 1), 60.0, jnp.float32)],
        axis=1,
    )
    scale = jnp.array([0.01] * (d - 1) + [0.5], jnp.float32)
    zp = jnp.full((d,), 128.0, jnp.float32)
    cfg = jnp.array([0.0, 255.0, 1.0], jnp.float32)
    dq = fake_quant(x, scale, zp, cfg)
    np.testing.assert_allclose(dq[:, : d - 1], x[:, : d - 1], atol=0.005 + 1e-6)
    np.testing.assert_allclose(dq[:, -1], x[:, -1], atol=0.25 + 1e-6)


# ---------------------------------------------------------------------------
# fake_quant_ste (QAT gradients)
# ---------------------------------------------------------------------------

def test_ste_grad_identity_inside_range():
    d = 8
    scale = jnp.full((d,), 0.1, jnp.float32)
    zp = jnp.full((d,), 128.0, jnp.float32)
    cfg = jnp.array([0.0, 255.0, 1.0], jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, (4, d)).astype(np.float32))
    g = jax.grad(lambda x: jnp.sum(fake_quant_ste(x, scale, zp, cfg)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), atol=1e-6)


def test_ste_grad_zero_outside_range():
    d = 4
    scale = jnp.full((d,), 0.1, jnp.float32)
    zp = jnp.full((d,), 128.0, jnp.float32)
    cfg = jnp.array([0.0, 255.0, 1.0], jnp.float32)
    x = jnp.full((2, d), 1e3, jnp.float32)  # far outside the grid
    g = jax.grad(lambda x: jnp.sum(fake_quant_ste(x, scale, zp, cfg)))(x)
    np.testing.assert_allclose(np.asarray(g), np.zeros_like(g), atol=1e-6)


def test_ste_scale_grad_matches_lsq_formula():
    # LSQ (Esser et al. 2019): d(dq)/ds = round(x/s) - x/s inside the grid,
    # and (clip - z) when clipped. NOTE this deliberately differs from the
    # local finite difference (round is piecewise constant); LSQ routes the
    # STE through the rounding.
    d = 3
    zp = jnp.zeros((d,), jnp.float32)
    cfg = jnp.array([-127.0, 127.0, 1.0], jnp.float32)
    s0 = 0.1
    x = jnp.array([[0.731, -0.52, 1e3]], jnp.float32)  # last elem clips
    scale = jnp.full((d,), s0, jnp.float32)

    g = jax.grad(lambda s: jnp.sum(fake_quant_ste(x, s, zp, cfg)))(scale)
    xs = np.asarray(x[0]) / s0
    want = np.where(
        np.abs(xs) <= 127, np.round(xs) - xs, np.clip(np.round(xs), -127, 127)
    )
    np.testing.assert_allclose(np.asarray(g), want, atol=1e-4)


def test_ste_disabled_grad_passthrough():
    d = 4
    scale = jnp.full((d,), 0.1, jnp.float32)
    zp = jnp.zeros((d,), jnp.float32)
    cfg = jnp.array([0.0, 255.0, 0.0], jnp.float32)  # disabled
    x = jnp.full((3, d), 1e3, jnp.float32)
    g = jax.grad(lambda x: jnp.sum(fake_quant_ste(x, scale, zp, cfg)))(x)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g), atol=1e-6)


# ---------------------------------------------------------------------------
# peg_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    t=st.integers(1, 49),
    d=st.sampled_from([8, 16, 32, 64]),
    n=st.sampled_from([4, 8, 16]),
    k=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_peg_matmul_matches_ref(t, d, n, k, seed):
    if d % k != 0:
        return
    rng = np.random.default_rng(seed)
    x = _rand(rng, (t, d), np.float32)
    w = _rand(rng, (d, n), np.float32, -1, 1)
    sx = jnp.asarray(rng.uniform(0.01, 0.3, size=(k,)).astype(np.float32))
    zx = jnp.asarray(rng.integers(0, 255, size=(k,)).astype(np.float32))
    sw = 0.01
    cfg = jnp.array([sw, 0.0, 255.0, -127.0, 127.0], jnp.float32)
    got = peg_matmul(x, w, sx, zx, cfg, num_groups=k)
    want = ref.peg_matmul_ref(x, w, sx, zx, sw, k, 0.0, 255.0, -127.0, 127.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_peg_k1_equals_per_tensor_eq3():
    # K=1 degenerates to the paper's Eq. (3): single re-scale per output.
    rng = np.random.default_rng(11)
    x = _rand(rng, (9, 16), np.float32)
    w = _rand(rng, (16, 8), np.float32, -1, 1)
    sx = jnp.array([0.05], jnp.float32)
    zx = jnp.array([128.0], jnp.float32)
    cfg = jnp.array([0.01, 0.0, 255.0, -127.0, 127.0], jnp.float32)
    got = peg_matmul(x, w, sx, zx, cfg, num_groups=1)
    xq = jnp.clip(jnp.round(x / sx[0]) + zx[0], 0, 255)
    wq = jnp.clip(jnp.round(w / 0.01), -127, 127)
    want = 0.01 * sx[0] * ((xq - zx[0]) @ wq)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_peg_finer_groups_reduce_error_on_outliers():
    # The paper's core claim: with outlier dims, more groups (after the
    # range-based permutation) => lower product error (Table 5 mechanism).
    rng = np.random.default_rng(5)
    t, d = 32, 16
    x = np.asarray(rng.uniform(-1, 1, (t, d)), np.float32)
    x[:, -2:] *= 80.0  # planted outlier dims (paper Fig. 2b)
    x = jnp.asarray(x)
    w = _rand(rng, (d, 8), np.float32, -1, 1)
    exact = x @ (jnp.clip(jnp.round(w / 0.01), -127, 127) * 0.01)

    def err(k):
        xs = np.asarray(x)
        r = xs.max(0) - xs.min(0)
        order = np.argsort(r)  # range-based permutation (paper §4)
        gs = d // k
        sx, zx = [], []
        perm = xs[:, order]
        for g in range(k):
            seg = perm[:, g * gs:(g + 1) * gs]
            lo, hi = float(seg.min()), float(seg.max())
            s = max((hi - lo) / 255.0, 1e-8)
            sx.append(s)
            zx.append(round(-lo / s))
        wp = np.asarray(w)[order, :]
        got = peg_matmul(
            jnp.asarray(perm), jnp.asarray(wp),
            jnp.asarray(np.array(sx, np.float32)),
            jnp.asarray(np.array(zx, np.float32)),
            jnp.array([0.01, 0.0, 255.0, -127.0, 127.0], jnp.float32),
            num_groups=k,
        )
        return float(jnp.mean((got - exact) ** 2))

    e1, e2, e8 = err(1), err(2), err(8)
    # K=2 still mixes 6 normal dims into the outlier group -> modest gain;
    # K=8 (groups of 2) isolates the outlier pair -> order-of-magnitude gain.
    assert e2 < e1, (e1, e2)
    assert e8 < e1 * 0.2, (e1, e8)
    assert e8 <= e2 + 1e-9, (e2, e8)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 80),
    d=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
    batched=st.booleans(),
)
def test_layernorm_matches_ref(rows, d, seed, batched):
    rng = np.random.default_rng(seed)
    shape = (3, rows, d) if batched else (rows, d)
    x = _rand(rng, shape, np.float32, -10, 10)
    gamma = _rand(rng, (d,), np.float32, 0.5, 2.0)
    beta = _rand(rng, (d,), np.float32, -1, 1)
    got = layernorm(x, gamma, beta)
    want = ref.layernorm_ref(x, gamma, beta)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_layernorm_output_is_normalized():
    rng = np.random.default_rng(9)
    x = _rand(rng, (20, 64), np.float32, -5, 5)
    out = layernorm(x, jnp.ones((64,)), jnp.zeros((64,)))
    np.testing.assert_allclose(np.asarray(jnp.mean(out, -1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(out, -1)), 1.0, atol=1e-3)


def test_layernorm_scale_invariance():
    # LayerNorm(a*x) == LayerNorm(x) for a > 0 (gamma=1, beta=0)
    rng = np.random.default_rng(10)
    x = _rand(rng, (7, 32), np.float32)
    g = jnp.ones((32,))
    b = jnp.zeros((32,))
    np.testing.assert_allclose(
        np.asarray(layernorm(3.7 * x, g, b)), np.asarray(layernorm(x, g, b)), atol=1e-4
    )
