"""L2: BERT-style transformer encoder with runtime-parameterised quantizers.

This is the paper's model substrate (Devlin et al. BERT-base, shrunk per
DESIGN.md §2).  Every activation-quantizer site the paper studies (Fig. 1 /
Table 2) is instrumented with a fake-quant op whose scale, zero-point and
[qmin, qmax, enable] config are *runtime inputs* to the lowered executable,
flattened into three tensors:

    act_scales : (S,)          concatenation of per-site scale vectors
    act_zps    : (S,)          matching zero-points
    act_cfg    : (n_sites, 3)  per-site [qmin, qmax, enable]

where a site contributes ``channels`` lanes (d or d_ff for embedding-axis
tensors, 1 for attention scores/probs and scalar-granularity sites).  The
Rust coordinator owns the whole quantization policy — per-tensor vs PEG
(with range-based permutation) vs per-embedding granularity, bit-widths and
mixed precision, leave-one-out ablation — simply by how it fills these
tensors (DESIGN.md §3).

Weight quantization is simulated on the parameter tensors by the Rust side
for PTQ; the QAT train-step graph additionally fake-quantizes weights
in-graph with learnable per-tensor scales (LSQ-style, paper §4 "QAT").

Graphs exported by aot.py:
    forward(...)          logits (evaluation hot path, Pallas kernels)
    forward(collect=True) logits + per-site FP32 taps (calibration & figures)
    fp32_train_step(...)  Adam fine-tune step w/ outlier-inducing aux loss
    qat_train_step(...)   STE fake-quant + learnable-range Adam step
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import fake_quant, fake_quant_ste, layernorm
from .kernels import ref as kref

PAD_ID, CLS_ID, SEP_ID = 0, 1, 2
MASK_BIAS = -30.0  # additive attention-mask bias; keeps softmax-input ranges
                   # finite so its quantizer sees a sane dynamic range
                   # (real BERT uses -1e4, which only works unquantized)
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters. Mirrored in rust/src/model/config.rs."""

    name: str = "base"
    # 64-token vocabulary: small enough that every token is seen hundreds
    # of times during fine-tuning, so the synthetic rules generalise from
    # 2048 examples (DESIGN.md §2)
    vocab: int = 64
    d: int = 128
    heads: int = 4
    layers: int = 6
    d_ff: int = 512
    seq: int = 64
    n_out: int = 3          # classification logits (first n_classes used);
                            # regression artifacts use n_out=1
    # embedding dims driven to large magnitude by the outlier-inducing aux
    # loss (substitute for pre-training-emergent outliers, DESIGN.md §2)
    outlier_dims: Tuple[int, ...] = (17, 89, 101)


# Model-size variants mirroring the paper's Appendix D architecture sweep
# (BERT-base / BERT-large / DistilRoBERTa / MobileBERT analogues).
CONFIGS = {
    "base": ModelConfig(name="base"),
    "large": ModelConfig(name="large", d=192, heads=6, layers=8, d_ff=768,
                         outlier_dims=(23, 131, 157)),
    "distil": ModelConfig(name="distil", layers=3),
    "mobile": ModelConfig(name="mobile", d=96, heads=4, layers=6, d_ff=192,
                          outlier_dims=(11, 61, 83)),
}


# ---------------------------------------------------------------------------
# Parameter & quantizer-site specs (canonical ordering shared with Rust)
# ---------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the executable's parameter signature."""
    spec = [
        ("embed.tok", (cfg.vocab, cfg.d)),
        ("embed.pos", (cfg.seq, cfg.d)),
        ("embed.type", (2, cfg.d)),
        ("embed.ln.g", (cfg.d,)),
        ("embed.ln.b", (cfg.d,)),
    ]
    for i in range(cfg.layers):
        p = f"layer{i}."
        spec += [
            (p + "q.w", (cfg.d, cfg.d)), (p + "q.b", (cfg.d,)),
            (p + "k.w", (cfg.d, cfg.d)), (p + "k.b", (cfg.d,)),
            (p + "v.w", (cfg.d, cfg.d)), (p + "v.b", (cfg.d,)),
            (p + "attn_out.w", (cfg.d, cfg.d)), (p + "attn_out.b", (cfg.d,)),
            (p + "ln1.g", (cfg.d,)), (p + "ln1.b", (cfg.d,)),
            (p + "ffn1.w", (cfg.d, cfg.d_ff)), (p + "ffn1.b", (cfg.d_ff,)),
            (p + "ffn2.w", (cfg.d_ff, cfg.d)), (p + "ffn2.b", (cfg.d,)),
            (p + "ln2.g", (cfg.d,)), (p + "ln2.b", (cfg.d,)),
        ]
    spec += [
        ("pool.w", (cfg.d, cfg.d)), ("pool.b", (cfg.d,)),
        ("head.w", (cfg.d, cfg.n_out)), ("head.b", (cfg.n_out,)),
    ]
    return spec


def site_spec(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """Ordered (site_name, channels) list of activation quantizers.

    These are the paper's Fig. 1 sites: qkv outputs, softmax input/output,
    attention context & output, both residual sums (res2_sum is the
    problematic FFN residual), LayerNorm outputs, FFN hidden/output,
    embedding sum, pooler and final head output.
    """
    sites = [("embed_sum", cfg.d), ("embed_ln_out", cfg.d)]
    for i in range(cfg.layers):
        p = f"layer{i}."
        sites += [
            (p + "q", cfg.d), (p + "k", cfg.d), (p + "v", cfg.d),
            (p + "attn_scores", 1),   # softmax input
            (p + "attn_probs", 1),    # softmax output
            (p + "attn_ctx", cfg.d),
            (p + "attn_out", cfg.d),  # self-attention output
            (p + "res1_sum", cfg.d),
            (p + "ln1_out", cfg.d),   # == FFN input
            (p + "ffn_hidden", cfg.d_ff),
            (p + "ffn_out", cfg.d),
            (p + "res2_sum", cfg.d),  # residual sum after FFN (the villain)
            (p + "ln2_out", cfg.d),
        ]
    sites += [("pooled", cfg.d), ("head_out", 1)]
    return sites


def wq_spec(cfg: ModelConfig) -> List[str]:
    """Weight tensors that get (learnable, for QAT) per-tensor quantizers."""
    names = ["embed.tok"]
    for i in range(cfg.layers):
        p = f"layer{i}."
        names += [p + "q.w", p + "k.w", p + "v.w",
                  p + "attn_out.w", p + "ffn1.w", p + "ffn2.w"]
    names += ["pool.w", "head.w"]
    return names


def site_offsets(cfg: ModelConfig):
    """(offsets, total) — lane offset of each site inside act_scales."""
    offs, total = [], 0
    for _, c in site_spec(cfg):
        offs.append(total)
        total += c
    return offs, total


def init_params(cfg: ModelConfig, key) -> List[jax.Array]:
    """Seeded init (truncated-normal-ish 0.02 std, as BERT)."""
    out = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".b"):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith(".g"):
            out.append(jnp.ones(shape, jnp.float32))
        else:
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

class _Quant:
    """Per-site fake-quant dispatcher reading the flat runtime tensors."""

    def __init__(self, cfg, act_scales, act_zps, act_cfg, ste: bool,
                 use_pallas: bool, taps=None, skip: bool = False):
        self.skip = skip
        self.cfg = cfg
        self.sites = site_spec(cfg)
        self.names = [n for n, _ in self.sites]
        self.chan = {n: c for n, c in self.sites}
        self.offs, _ = site_offsets(cfg)
        self.off = {n: o for (n, _), o in zip(self.sites, self.offs)}
        self.scales, self.zps, self.qcfg = act_scales, act_zps, act_cfg
        self.ste = ste
        self.use_pallas = use_pallas
        self.taps = taps  # dict site -> FP32 tensor (pre-quant), or None

    def __call__(self, name, x):
        if self.taps is not None:
            self.taps[name] = x
        if self.skip:
            # FP32 training path: no quantization ops in the graph at all
            # (cheaper than computing dq and select-ing it away at runtime)
            return x
        c = self.chan[name]
        o = self.off[name]
        idx = self.names.index(name)
        s = self.scales[o:o + c]   # static slice: o, c are Python ints
        z = self.zps[o:o + c]
        q3 = self.qcfg[idx]
        d_last = x.shape[-1]
        if c == 1:
            s = jnp.broadcast_to(s, (d_last,))
            z = jnp.broadcast_to(z, (d_last,))
        if self.ste:
            return fake_quant_ste(x, s, z, q3)
        if self.use_pallas:
            return fake_quant(x, s, z, q3)
        return kref.fake_quant_ref(x, s, z, q3[0], q3[1], q3[2])


def _ln(x, g, b, use_pallas):
    return layernorm(x, g, b) if use_pallas else kref.layernorm_ref(x, g, b)


def forward(cfg: ModelConfig, params: List[jax.Array],
            act_scales, act_zps, act_cfg,
            input_ids, token_type, attn_mask,
            *, collect_taps: bool = False, ste: bool = False,
            use_pallas: bool = True, skip_quant: bool = False):
    """Encoder forward.

    Args:
      params:     list in ``param_spec`` order.
      act_*:      flat quantizer tensors (see module docstring).
      input_ids:  (B, T) int32.
      token_type: (B, T) int32 segment ids (0 / 1).
      attn_mask:  (B, T) float32, 1 for real tokens, 0 for [PAD].

    Returns (logits, taps) where taps is a dict of FP32 site tensors when
    ``collect_taps`` else None.
    """
    names = [n for n, _ in param_spec(cfg)]
    P = {n: p for n, p in zip(names, params)}
    taps = {} if collect_taps else None
    Q = _Quant(cfg, act_scales, act_zps, act_cfg, ste, use_pallas, taps,
               skip=skip_quant)

    B, T = input_ids.shape
    d, h = cfg.d, cfg.heads
    dh = d // h

    x = (P["embed.tok"][input_ids]
         + P["embed.pos"][None, :T, :]
         + P["embed.type"][token_type])
    x = Q("embed_sum", x)
    x = _ln(x, P["embed.ln.g"], P["embed.ln.b"], use_pallas)
    x = Q("embed_ln_out", x)

    bias = (1.0 - attn_mask)[:, None, None, :] * MASK_BIAS

    for i in range(cfg.layers):
        p = f"layer{i}."
        q = Q(p + "q", x @ P[p + "q.w"] + P[p + "q.b"])
        k = Q(p + "k", x @ P[p + "k.w"] + P[p + "k.b"])
        v = Q(p + "v", x @ P[p + "v.w"] + P[p + "v.b"])
        # (B, h, T, dh)
        q = q.reshape(B, T, h, dh).transpose(0, 2, 1, 3)
        k = k.reshape(B, T, h, dh).transpose(0, 2, 1, 3)
        v = v.reshape(B, T, h, dh).transpose(0, 2, 1, 3)
        scores = q @ k.transpose(0, 1, 3, 2) / jnp.sqrt(float(dh)) + bias
        scores = Q(p + "attn_scores", scores)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = Q(p + "attn_probs", probs)
        ctx = (probs @ v).transpose(0, 2, 1, 3).reshape(B, T, d)
        ctx = Q(p + "attn_ctx", ctx)
        attn_out = Q(p + "attn_out", ctx @ P[p + "attn_out.w"] + P[p + "attn_out.b"])
        x = Q(p + "res1_sum", x + attn_out)
        x = _ln(x, P[p + "ln1.g"], P[p + "ln1.b"], use_pallas)
        x = Q(p + "ln1_out", x)          # FFN input
        hdn = jax.nn.gelu(x @ P[p + "ffn1.w"] + P[p + "ffn1.b"],
                          approximate=False)
        hdn = Q(p + "ffn_hidden", hdn)
        ffn_out = Q(p + "ffn_out", hdn @ P[p + "ffn2.w"] + P[p + "ffn2.b"])
        x = Q(p + "res2_sum", x + ffn_out)   # the problematic residual
        x = _ln(x, P[p + "ln2.g"], P[p + "ln2.b"], use_pallas)
        x = Q(p + "ln2_out", x)

    pooled = Q("pooled", jnp.tanh(x[:, 0, :] @ P["pool.w"] + P["pool.b"]))
    logits = Q("head_out", pooled @ P["head.w"] + P["head.b"])
    return logits, taps


# ---------------------------------------------------------------------------
# Losses & training steps (Adam fused in-graph; Rust drives the loop)
# ---------------------------------------------------------------------------

def _task_loss(cfg, logits, labels, regression: bool):
    if regression:
        return jnp.mean((logits[:, 0] - labels) ** 2)
    onehot = jax.nn.one_hot(labels, cfg.n_out)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def _outlier_aux_loss(cfg, taps, input_ids, aux_target):
    """Drive designated FFN-output embedding dims to ``aux_target`` at [SEP].

    Substitute for the pre-training-emergent structured outliers of paper
    Fig. 2 / Appendix A: a few designated dims of the FFN output take large
    values, strongest at separator positions. Creates the FFN-residual
    dynamic-range mismatch that per-tensor W8A8 cannot represent.
    """
    sep = (input_ids == SEP_ID).astype(jnp.float32)          # (B, T)
    n_sep = jnp.maximum(jnp.sum(sep), 1.0)
    n_rest = jnp.maximum(jnp.sum(1.0 - sep), 1.0)
    dims = jnp.array(cfg.outlier_dims, jnp.int32)
    # DEEPEST layer only: the paper finds the issue "most pronounced for
    # deeper encoder layers (10 and 11)". Installing outliers mid-stack
    # corrupts the residual stream the task still needs (later attention
    # reads the spiked keys); the last layer's FFN output feeds only the
    # final LayerNorm, and the [CLS] position — which the pooler reads —
    # is pinned to zero in the outlier dims, so the task is unaffected.
    i = cfg.layers - 1
    t = taps[f"layer{i}.ffn_out"][..., dims]                 # (B, T, k)
    at_sep = jnp.sum(((t - aux_target) ** 2) * sep[..., None]) / n_sep
    # pin the same dims near zero elsewhere — otherwise the model
    # satisfies the [SEP] target with a constant bias shift and the
    # outliers lose their token structure (paper Fig. 2a)
    elsewhere = 0.1 * jnp.sum((t ** 2) * (1.0 - sep)[..., None]) / n_rest
    return at_sep + elsewhere


def _adam(params, grads, m, v, lr_eff):
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1 - ADAM_B2) * g * g
        new_m.append(mi)
        new_v.append(vi)
        new_p.append(p - lr_eff * mi / (jnp.sqrt(vi) + ADAM_EPS))
    return new_p, new_m, new_v


def fp32_train_step(cfg: ModelConfig, params, m, v,
                    input_ids, token_type, attn_mask, labels,
                    lr_eff, aux_lambda, aux_target, *, regression: bool):
    """One FP32 Adam fine-tuning step with the outlier-inducing aux loss.

    ``lr_eff`` must already include Adam bias correction and LR schedule
    (computed by the Rust coordinator). Returns (params', m', v', loss).
    """
    n_sites = len(site_spec(cfg))
    _, S = site_offsets(cfg)
    # quantizers disabled: enable=0 in every site's cfg row
    zs = jnp.ones((S,), jnp.float32)
    zz = jnp.zeros((S,), jnp.float32)
    zc = jnp.tile(jnp.array([[0.0, 255.0, 0.0]], jnp.float32), (n_sites, 1))

    def loss_fn(ps):
        logits, taps = forward(cfg, ps, zs, zz, zc, input_ids, token_type,
                               attn_mask, collect_taps=True, use_pallas=False,
                               skip_quant=True)
        task = _task_loss(cfg, logits, labels, regression)
        aux = _outlier_aux_loss(cfg, taps, input_ids, aux_target)
        return task + aux_lambda * aux, task

    grads, task = jax.grad(loss_fn, has_aux=True)(params)
    new_p, new_m, new_v = _adam(params, grads, m, v, lr_eff)
    return new_p, new_m, new_v, task


def qat_train_step(cfg: ModelConfig, params, m, v,
                   act_scales, ms, vs, act_zps, act_cfg,
                   wq_scales, mw, vw, wq_cfg,
                   input_ids, token_type, attn_mask, labels,
                   lr_eff, lr_s_eff, *, regression: bool):
    """One QAT step: STE fake-quant on activations AND weights, learnable
    ranges for both (paper §4 'Quantization-aware training', LSQ-style).

    wq_scales: (n_wq,) per-tensor weight scales; wq_cfg: (n_wq, 3).
    Returns (params', m', v', act_scales', ms', vs', wq_scales', mw', vw',
    loss).
    """
    wq_names = wq_spec(cfg)
    pnames = [n for n, _ in param_spec(cfg)]
    widx = {n: j for j, n in enumerate(wq_names)}

    def loss_fn(ps, a_scales, w_scales):
        qps = []
        for n, p in zip(pnames, ps):
            if n in widx:
                j = widx[n]
                s = jnp.broadcast_to(w_scales[j][None], (p.shape[-1],))
                z = jnp.zeros((p.shape[-1],), jnp.float32)
                qps.append(fake_quant_ste(p, s, z, wq_cfg[j]))
            else:
                qps.append(p)
        logits, _ = forward(cfg, qps, a_scales, act_zps, act_cfg,
                            input_ids, token_type, attn_mask,
                            ste=True, use_pallas=False)
        return _task_loss(cfg, logits, labels, regression)

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
        params, act_scales, wq_scales)
    gp, ga, gw = grads
    new_p, new_m, new_v = _adam(params, gp, m, v, lr_eff)
    # scale vectors ride the same Adam machinery
    [ns], [nms], [nvs] = _adam([act_scales], [ga], [ms], [vs], lr_s_eff)
    [nw], [nmw], [nvw] = _adam([wq_scales], [gw], [mw], [vw], lr_s_eff)
    # scales must stay strictly positive
    ns = jnp.maximum(ns, 1e-6)
    nw = jnp.maximum(nw, 1e-6)
    return new_p, new_m, new_v, ns, nms, nvs, nw, nmw, nvw, loss
