"""Pallas per-embedding-group quantized matmul (paper Eq. 4/5).

The paper's key hardware observation: with per-tensor activation scales the
integer accumulator needs ONE re-scale per output (Eq. 3); with
per-embedding scales it needs d re-scales (Eq. 4); PEG with K groups needs
only K (Eq. 5).  This kernel implements the K-group schedule directly:

  for each row tile:                         # grid over T
    acc = 0
    for g in 0..K:                           # static unroll, K small
      xq_g  = quantize(x[:, g])              # int grid, affine
      acc  += s_g * ((xq_g - z_g) @ wq[g])   # integer-domain matmul per
                                             #   group, ONE re-scale each
    out = s_w * acc

TPU mapping (DESIGN.md §4): each group's (rows × d/K)·(d/K × n) product is
an MXU pass over a VMEM-resident weight slice; the group re-scale is a
single VPU multiply on the accumulator tile between passes — K multiplies
total, which is exactly the cost model that motivates small K in the paper.

interpret=True (CPU PJRT cannot run Mosaic).  Weight quantization is
symmetric per-tensor, activations affine per-group, as in the paper.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 16


def _peg_kernel(x_ref, w_ref, sx_ref, zx_ref, cfg_ref, o_ref, *, num_groups):
    x = x_ref[...]          # (block, d)
    w = w_ref[...]          # (d, n)
    sw = cfg_ref[0]
    qmin_a, qmax_a = cfg_ref[1], cfg_ref[2]
    qmin_w, qmax_w = cfg_ref[3], cfg_ref[4]
    d = x.shape[1]
    gs = d // num_groups
    wq = jnp.clip(jnp.round(w / sw), qmin_w, qmax_w)
    acc = jnp.zeros((x.shape[0], w.shape[1]), x.dtype)
    for g in range(num_groups):     # static: K is a compile-time constant
        xs = x[:, g * gs:(g + 1) * gs]
        xq = jnp.clip(jnp.round(xs / sx_ref[g]) + zx_ref[g], qmin_a, qmax_a)
        acc = acc + sx_ref[g] * ((xq - zx_ref[g]) @ wq[g * gs:(g + 1) * gs, :])
    o_ref[...] = sw * acc


@functools.partial(jax.jit, static_argnames=("num_groups",))
def peg_matmul(x, w, sx, zx, cfg, *, num_groups):
    """PEG-quantized matmul.

    Args:
      x:   (T, d) activations.
      w:   (d, n) weights.
      sx:  (num_groups,) activation scales.
      zx:  (num_groups,) activation zero points.
      cfg: (5,) = [sw, qmin_a, qmax_a, qmin_w, qmax_w].
      num_groups: K, must divide d (static).

    Returns (T, n) = dequantized product.
    """
    T, d = x.shape
    n = w.shape[1]
    assert d % num_groups == 0, "num_groups must divide d"
    pad = (-T) % _BLOCK_ROWS
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0)
    rows = x.shape[0]

    out = pl.pallas_call(
        functools.partial(_peg_kernel, num_groups=num_groups),
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((_BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
            pl.BlockSpec((num_groups,), lambda i: (0,)),
            pl.BlockSpec((num_groups,), lambda i: (0,)),
            pl.BlockSpec((5,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), x.dtype),
        interpret=True,
    )(x, w, sx.astype(x.dtype), zx.astype(x.dtype), cfg.astype(x.dtype))

    if pad:
        out = out[:T]
    return out
