# L1: Pallas kernels for the paper's quantization hot-spots.
from .fake_quant import fake_quant, fake_quant_ste
from .layernorm import layernorm
from .peg_matmul import peg_matmul

__all__ = ["fake_quant", "fake_quant_ste", "layernorm", "peg_matmul"]
