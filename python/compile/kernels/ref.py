"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float tolerance (pytest + hypothesis enforce it).
They also serve as the executable specification of the paper's equations:

  * ``fake_quant_ref``  — Eq. (1)+(2): uniform affine quantize-dequantize
    with a per-embedding-dim scale/zero-point vector (subsumes per-tensor,
    per-embedding-group, and per-embedding granularity, see DESIGN.md §3).
  * ``peg_matmul_ref``  — Eq. (4)/(5): integer-simulated matmul with
    per-embedding-group activation scales and grouped accumulator
    re-scaling.
  * ``layernorm_ref``   — standard LayerNorm over the last dim.
"""

import jax.numpy as jnp


def fake_quant_ref(x, scale, zero_point, qmin, qmax, enable):
    """Uniform affine quantize-dequantize (paper Eq. 1-2), per-dim vectors.

    Args:
      x:          (..., d) real-valued tensor.
      scale:      (d,) positive scale per embedding dim (broadcast per-tensor
                  granularity by repeating one scalar).
      zero_point: (d,) zero points (float-carried integers).
      qmin, qmax: scalar integer grid limits as floats (e.g. 0, 255).
      enable:     scalar; <= 0 means pass-through (FP32 ablation).

    Returns (..., d) dequantized tensor.
    """
    q = jnp.clip(jnp.round(x / scale) + zero_point, qmin, qmax)
    dq = scale * (q - zero_point)
    return jnp.where(enable > 0, dq, x)


def peg_matmul_ref(x, w, sx, zx, sw, num_groups, qmin_a, qmax_a, qmin_w, qmax_w):
    """Per-embedding-group quantized matmul oracle (paper Eq. 4/5).

    The activation tensor ``x`` (T, d) is quantized with ``num_groups``
    distinct (scale, zero-point) pairs along the embedding dim; the weight
    ``w`` (d, n) symmetrically per-tensor.  The product is accumulated in the
    integer domain per group and re-scaled once per group — the K re-scalings
    (instead of d) that make PEG hardware-friendly.

    sx, zx: (num_groups,) activation quant params.  sw: scalar weight scale.
    """
    T, d = x.shape
    gs = d // num_groups
    wq = jnp.clip(jnp.round(w / sw), qmin_w, qmax_w)
    out = jnp.zeros((T, w.shape[1]), dtype=x.dtype)
    for g in range(num_groups):
        xs = x[:, g * gs:(g + 1) * gs]
        xq = jnp.clip(jnp.round(xs / sx[g]) + zx[g], qmin_a, qmax_a)
        # integer-domain accumulate, then one re-scale for the whole group
        acc = (xq - zx[g]) @ wq[g * gs:(g + 1) * gs, :]
        out = out + sx[g] * acc
    return sw * out


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last dimension."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
