"""Pallas fused LayerNorm kernel.

LayerNorm appears twice per encoder layer and brackets the paper's
problematic residual sums (Fig. 1), so it sits on the hot path of every
forward.  One row tile per grid step: mean/variance reduction and the
affine transform fuse into a single VMEM-resident pass (on TPU this is a
pure VPU op; here interpret=True lowers it to plain HLO).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 32
_EPS = 1e-5


def _ln_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + _EPS) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=())
def layernorm(x, gamma, beta):
    """LayerNorm over the last dim of ``x`` (..., d)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    pad = (-n) % _BLOCK_ROWS
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x2.dtype)], axis=0)
    rows = x2.shape[0]

    out = pl.pallas_call(
        _ln_kernel,
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((_BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x2.dtype),
        interpret=True,
    )(x2, gamma.astype(x2.dtype), beta.astype(x2.dtype))

    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
