"""Pallas fake-quantization kernel (paper Eq. 1-2).

Uniform affine quantize-dequantize with a per-embedding-dim scale /
zero-point vector.  This single kernel subsumes every activation
granularity the paper studies (DESIGN.md §3):

  * per-tensor       — one scalar repeated across all d lanes,
  * per-embedding-group (PEG, K groups, optionally range-permuted) —
    group scales repeated over their member dims,
  * per-embedding    — a distinct scale per dim.

``qmin``/``qmax``/``enable`` ride in a small scalar vector so the *same*
lowered HLO serves 2..16-bit and FP32-ablation configurations at runtime.

Run with ``interpret=True`` everywhere: the CPU PJRT client cannot execute
Mosaic custom-calls.  On a real TPU the natural layout is the same: the
(rows × d) block lives in VMEM, the scale vector is broadcast along the
sublane axis, and the whole op is VPU element-wise work fused between two
MXU matmuls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size for the Pallas grid. 32 rows x d lanes comfortably fits VMEM
# for every d used in this repo (d <= 768).
_BLOCK_ROWS = 32


def _fq_kernel(x_ref, s_ref, z_ref, cfg_ref, o_ref):
    x = x_ref[...]
    s = s_ref[...]
    z = z_ref[...]
    qmin = cfg_ref[0]
    qmax = cfg_ref[1]
    enable = cfg_ref[2]
    q = jnp.clip(jnp.round(x / s) + z, qmin, qmax)
    dq = s * (q - z)
    o_ref[...] = jnp.where(enable > 0, dq, x)


@functools.partial(jax.jit, static_argnames=())
def fake_quant(x, scale, zero_point, cfg):
    """Quantize-dequantize ``x`` (..., d) with per-dim vectors.

    Args:
      x:          (..., d) tensor.
      scale:      (d,) scales.
      zero_point: (d,) zero points.
      cfg:        (3,) = [qmin, qmax, enable].

    Returns the dequantized tensor, same shape as ``x``.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    # pad rows to a multiple of the block so the grid divides evenly
    pad = (-n) % _BLOCK_ROWS
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x2.dtype)], axis=0)
    rows = x2.shape[0]

    out = pl.pallas_call(
        _fq_kernel,
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((_BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((_BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x2.dtype),
        interpret=True,
    )(x2, scale.astype(x2.dtype), zero_point.astype(x2.dtype), cfg.astype(x2.dtype))

    if pad:
        out = out[:n]
    return out.reshape(orig_shape)


def _fq_math(x, scale, zero_point, cfg):
    """Pure-jnp fake-quant, numerically identical to the Pallas kernel.

    Used as the forward of the STE op so QAT training graphs stay lean
    (the Pallas kernel serves the inference/calibration hot path; both are
    verified against the same ref.py oracle).
    """
    q = jnp.clip(jnp.round(x / scale) + zero_point, cfg[0], cfg[1])
    dq = scale * (q - zero_point)
    return jnp.where(cfg[2] > 0, dq, x)


@jax.custom_vjp
def fake_quant_ste(x, scale, zero_point, cfg):
    """fake_quant with a straight-through estimator for QAT (paper §4).

    Backward: gradients pass through the rounding unchanged for x inside
    the clipping range and are zeroed outside (clipped-STE); the scale
    gradient follows LSQ (Esser et al., 2019) / Jain et al. (2019) so
    ranges are learnable during QAT.
    """
    return _fq_math(x, scale, zero_point, cfg)


def _fq_fwd(x, scale, zero_point, cfg):
    return _fq_math(x, scale, zero_point, cfg), (x, scale, zero_point, cfg)


def _fq_bwd(res, g):
    x, scale, zero_point, cfg = res
    qmin, qmax, enable = cfg[0], cfg[1], cfg[2]
    xs = x / scale + zero_point
    inside = jnp.logical_and(xs >= qmin, xs <= qmax)
    # clipped straight-through for x (identity when quantizer disabled)
    gx = jnp.where(jnp.logical_or(inside, enable <= 0), g, 0.0)
    # LSQ scale gradient: d(dq)/ds = (round(x/s) + z - z) - x/s  inside range,
    #                               (clip - z)                   outside.
    q = jnp.clip(jnp.round(xs), qmin, qmax)
    ds_elem = jnp.where(inside, jnp.round(xs) - xs, q - zero_point)
    reduce_axes = tuple(range(x.ndim - 1))
    gs = jnp.where(enable > 0, jnp.sum(g * ds_elem, axis=reduce_axes), 0.0)
    gz = jnp.zeros_like(zero_point)  # zero-points stay fixed during QAT
    gcfg = jnp.zeros_like(cfg)
    return gx, gs, gz, gcfg


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)
