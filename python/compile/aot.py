"""AOT export: lower every L2 graph to HLO *text* + emit a JSON manifest.

HLO text (NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` via serialized
protos) is the interchange format: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts (all under artifacts/):
    fwd_{cls,reg}_b{1,8}.hlo.txt      eval forward  -> (logits,)
    diag_{cls,reg}_b1.hlo.txt         forward + FP32 taps for calibration,
                                      range estimation, AdaRound & figures
    diag_{large,distil,mobile}_b1     architecture-sweep diagnostics
                                      (paper Fig. 10-13 analogues)
    train_fp32_{cls,reg}_b16          Adam fine-tune step (+aux outlier loss)
    train_qat_{cls,reg}_b16           QAT step (STE + learnable ranges)
    kernel_peg_k{1,3,6,16}.hlo.txt    standalone PEG matmul (d=768) for the
                                      re-scaling-overhead benches
    kernel_fq_d768.hlo.txt            standalone fake-quant kernel
    manifest.json                     machine-readable signatures for Rust

The manifest pins the exact flat input/output ordering of every executable
plus the model topology (param/site/weight-quantizer specs), so the Rust
coordinator can assemble argument lists without re-deriving anything.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import fake_quant, peg_matmul

jax.config.update("jax_platform_name", "cpu")

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(
        tuple(shape), jnp.int32 if dtype == I32 else jnp.float32)


class Sig:
    """Collects a flat (name, shape, dtype) input signature."""

    def __init__(self):
        self.inputs = []

    def add(self, name, shape, dtype=F32):
        self.inputs.append({"name": name, "shape": list(shape), "dtype": dtype})
        return spec(shape, dtype)

    def add_params(self, cfg, prefix="param."):
        return [self.add(prefix + n, s) for n, s in M.param_spec(cfg)]


def quant_input_shapes(cfg):
    _, S = M.site_offsets(cfg)
    n_sites = len(M.site_spec(cfg))
    return S, n_sites


def export_forward(cfg, batch, n_out, diag: bool, use_pallas=False):
    """Forward (or diagnostic) graph + its signature.

    use_pallas=True lowers the L1 Pallas kernels (interpret mode) into the
    graph; the default lowers the numerically-identical jnp form, which XLA
    CPU fuses ~3x faster (interpret-mode grid loops serialise on 1 core —
    see EXPERIMENTS.md §Perf). Both paths are verified equal by
    tests/test_model.py::test_pallas_and_jnp_paths_agree and the
    fwd_cls_b1_pallas parity artifact.
    """
    hcfg = M.ModelConfig(**{**cfg.__dict__, "n_out": n_out})
    S, n_sites = quant_input_shapes(hcfg)
    sig = Sig()
    p_specs = sig.add_params(hcfg)
    a_s = sig.add("act_scales", (S,))
    a_z = sig.add("act_zps", (S,))
    a_c = sig.add("act_cfg", (n_sites, 3))
    ids = sig.add("input_ids", (batch, hcfg.seq), I32)
    tt = sig.add("token_type", (batch, hcfg.seq), I32)
    mask = sig.add("attn_mask", (batch, hcfg.seq))

    site_names = [n for n, _ in M.site_spec(hcfg)]

    def fn(*flat):
        np_ = len(M.param_spec(hcfg))
        params = list(flat[:np_])
        a_scales, a_zps, a_cfg, input_ids, token_type, attn_mask = flat[np_:]
        logits, taps = M.forward(
            hcfg, params, a_scales, a_zps, a_cfg,
            input_ids, token_type, attn_mask,
            collect_taps=diag, use_pallas=use_pallas)
        if diag:
            return (logits,) + tuple(taps[n] for n in site_names)
        return (logits,)

    flat_specs = p_specs + [a_s, a_z, a_c, ids, tt, mask]
    lowered = jax.jit(fn).lower(*flat_specs)
    outputs = [{"name": "logits", "shape": [batch, n_out], "dtype": F32}]
    if diag:
        # shapes of taps: re-derive by abstract eval
        shapes = jax.eval_shape(fn, *flat_specs)
        for n, sh in zip(site_names, shapes[1:]):
            outputs.append({"name": "tap." + n, "shape": list(sh.shape),
                            "dtype": F32})
    return lowered, sig.inputs, outputs


def export_train_fp32(cfg, batch, n_out, regression):
    hcfg = M.ModelConfig(**{**cfg.__dict__, "n_out": n_out})
    sig = Sig()
    p = sig.add_params(hcfg, "param.")
    m = sig.add_params(hcfg, "m.")
    v = sig.add_params(hcfg, "v.")
    ids = sig.add("input_ids", (batch, hcfg.seq), I32)
    tt = sig.add("token_type", (batch, hcfg.seq), I32)
    mask = sig.add("attn_mask", (batch, hcfg.seq))
    labels = sig.add("labels", (batch,), F32 if regression else I32)
    lr = sig.add("lr_eff", ())
    lam = sig.add("aux_lambda", ())
    tgt = sig.add("aux_target", ())

    np_ = len(M.param_spec(hcfg))

    def fn(*flat):
        params = list(flat[:np_])
        ms = list(flat[np_:2 * np_])
        vs = list(flat[2 * np_:3 * np_])
        ids_, tt_, mask_, labels_, lr_, lam_, tgt_ = flat[3 * np_:]
        new_p, new_m, new_v, loss = M.fp32_train_step(
            hcfg, params, ms, vs, ids_, tt_, mask_, labels_,
            lr_, lam_, tgt_, regression=regression)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    flat_specs = p + m + v + [ids, tt, mask, labels, lr, lam, tgt]
    lowered = jax.jit(fn).lower(*flat_specs)
    outputs = ([{"name": "param." + n, "shape": list(s), "dtype": F32}
                for n, s in M.param_spec(hcfg)]
               + [{"name": "m." + n, "shape": list(s), "dtype": F32}
                  for n, s in M.param_spec(hcfg)]
               + [{"name": "v." + n, "shape": list(s), "dtype": F32}
                  for n, s in M.param_spec(hcfg)]
               + [{"name": "loss", "shape": [], "dtype": F32}])
    return lowered, sig.inputs, outputs


def export_train_qat(cfg, batch, n_out, regression):
    hcfg = M.ModelConfig(**{**cfg.__dict__, "n_out": n_out})
    S, n_sites = quant_input_shapes(hcfg)
    n_wq = len(M.wq_spec(hcfg))
    sig = Sig()
    p = sig.add_params(hcfg, "param.")
    m = sig.add_params(hcfg, "m.")
    v = sig.add_params(hcfg, "v.")
    a_s = sig.add("act_scales", (S,))
    msv = sig.add("m_scales", (S,))
    vsv = sig.add("v_scales", (S,))
    a_z = sig.add("act_zps", (S,))
    a_c = sig.add("act_cfg", (n_sites, 3))
    w_s = sig.add("wq_scales", (n_wq,))
    mwv = sig.add("m_wq", (n_wq,))
    vwv = sig.add("v_wq", (n_wq,))
    w_c = sig.add("wq_cfg", (n_wq, 3))
    ids = sig.add("input_ids", (batch, hcfg.seq), I32)
    tt = sig.add("token_type", (batch, hcfg.seq), I32)
    mask = sig.add("attn_mask", (batch, hcfg.seq))
    labels = sig.add("labels", (batch,), F32 if regression else I32)
    lr = sig.add("lr_eff", ())
    lrs = sig.add("lr_s_eff", ())

    np_ = len(M.param_spec(hcfg))

    def fn(*flat):
        params = list(flat[:np_])
        ms = list(flat[np_:2 * np_])
        vs = list(flat[2 * np_:3 * np_])
        (a_scales, m_s, v_s, a_zps, a_cfg, wq_scales, m_w, v_w, wq_cfg,
         ids_, tt_, mask_, labels_, lr_, lrs_) = flat[3 * np_:]
        out = M.qat_train_step(
            hcfg, params, ms, vs, a_scales, m_s, v_s, a_zps, a_cfg,
            wq_scales, m_w, v_w, wq_cfg, ids_, tt_, mask_, labels_,
            lr_, lrs_, regression=regression)
        (new_p, new_m, new_v, ns, nms, nvs, nw, nmw, nvw, loss) = out
        return (tuple(new_p) + tuple(new_m) + tuple(new_v)
                + (ns, nms, nvs, nw, nmw, nvw, loss))

    flat_specs = (p + m + v
                  + [a_s, msv, vsv, a_z, a_c, w_s, mwv, vwv, w_c,
                     ids, tt, mask, labels, lr, lrs])
    lowered = jax.jit(fn).lower(*flat_specs)
    outputs = ([{"name": "param." + n, "shape": list(s), "dtype": F32}
                for n, s in M.param_spec(hcfg)]
               + [{"name": "m." + n, "shape": list(s), "dtype": F32}
                  for n, s in M.param_spec(hcfg)]
               + [{"name": "v." + n, "shape": list(s), "dtype": F32}
                  for n, s in M.param_spec(hcfg)]
               + [{"name": n, "shape": sh, "dtype": F32} for n, sh in [
                   ("act_scales", [S]), ("m_scales", [S]), ("v_scales", [S]),
                   ("wq_scales", [n_wq]), ("m_wq", [n_wq]), ("v_wq", [n_wq]),
                   ("loss", [])]])
    return lowered, sig.inputs, outputs


def export_kernel_peg(k, t=128, d=768, n=768):
    sig = Sig()
    x = sig.add("x", (t, d))
    w = sig.add("w", (d, n))
    sx = sig.add("sx", (k,))
    zx = sig.add("zx", (k,))
    cfg = sig.add("cfg", (5,))

    def fn(x, w, sx, zx, cfg):
        return (peg_matmul(x, w, sx, zx, cfg, num_groups=k),)

    lowered = jax.jit(fn).lower(x, w, sx, zx, cfg)
    outputs = [{"name": "out", "shape": [t, n], "dtype": F32}]
    return lowered, sig.inputs, outputs


def export_kernel_fq(t=128, d=768):
    sig = Sig()
    x = sig.add("x", (t, d))
    s = sig.add("scale", (d,))
    z = sig.add("zp", (d,))
    c = sig.add("cfg", (3,))

    def fn(x, s, z, c):
        return (fake_quant(x, s, z, c),)

    lowered = jax.jit(fn).lower(x, s, z, c)
    outputs = [{"name": "out", "shape": [t, d], "dtype": F32}]
    return lowered, sig.inputs, outputs


def model_info(cfg):
    offs, S = M.site_offsets(cfg)
    return {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "d": cfg.d,
            "heads": cfg.heads, "layers": cfg.layers, "d_ff": cfg.d_ff,
            "seq": cfg.seq, "n_out": cfg.n_out,
            "outlier_dims": list(cfg.outlier_dims),
            "pad_id": M.PAD_ID, "cls_id": M.CLS_ID, "sep_id": M.SEP_ID,
            "mask_bias": M.MASK_BIAS,
        },
        "params": [{"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)],
        "sites": [{"name": n, "channels": c, "offset": o}
                  for (n, c), o in zip(M.site_spec(cfg), offs)],
        "total_scale_lanes": S,
        "wq": M.wq_spec(cfg),
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
    }


def golden_fake_quant():
    """Tiny golden vectors so Rust's quant sim can be tested bit-exactly
    against the Python kernel."""
    rng = np.random.default_rng(1234)
    x = rng.uniform(-4, 4, (5, 8)).astype(np.float32)
    scale = rng.uniform(0.01, 0.3, (8,)).astype(np.float32)
    zp = rng.integers(0, 255, (8,)).astype(np.float32)
    cfg = np.array([0.0, 255.0, 1.0], np.float32)
    out = np.asarray(fake_quant(jnp.asarray(x), jnp.asarray(scale),
                                jnp.asarray(zp), jnp.asarray(cfg)))
    return {
        "x": x.flatten().tolist(), "scale": scale.tolist(),
        "zp": zp.tolist(), "qmin": 0.0, "qmax": 255.0,
        "rows": 5, "cols": 8, "out": out.flatten().tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only base fwd/diag (for CI smoke)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    base = M.CONFIGS["base"]
    manifest = {"artifacts": {}, "models": {}, "golden": {}}

    jobs = []
    # eval forwards
    for head, n_out in (("cls", 3), ("reg", 1)):
        for b in (1, 8):
            jobs.append((f"fwd_{head}_b{b}",
                         lambda h=head, no=n_out, bb=b:
                         export_forward(base, bb, no, diag=False)))
        jobs.append((f"diag_{head}_b1",
                     lambda h=head, no=n_out:
                     export_forward(base, 1, no, diag=True)))
    # Pallas-kernel forward (parity + kernel-in-graph benchmarks)
    jobs.append(("fwd_cls_b1_pallas",
                 lambda: export_forward(base, 1, 3, diag=False,
                                        use_pallas=True)))
    if not args.quick:
        # train steps
        for head, n_out, reg in (("cls", 3, False), ("reg", 1, True)):
            jobs.append((f"train_fp32_{head}_b16",
                         lambda no=n_out, r=reg:
                         export_train_fp32(base, 16, no, r)))
            jobs.append((f"train_qat_{head}_b16",
                         lambda no=n_out, r=reg:
                         export_train_qat(base, 16, no, r)))
        # architecture sweep diagnostics + variant fine-tuning (Fig. 9-13)
        for vname in ("large", "distil", "mobile"):
            jobs.append((f"diag_{vname}_b1",
                         lambda v=vname:
                         export_forward(M.CONFIGS[v], 1, 3, diag=True)))
            jobs.append((f"train_fp32_{vname}_b16",
                         lambda v=vname:
                         export_train_fp32(M.CONFIGS[v], 16, 3, False)))
        # standalone kernels for the PEG-overhead benches
        for k in (1, 3, 6, 16):
            jobs.append((f"kernel_peg_k{k}",
                         lambda kk=k: export_kernel_peg(kk)))
        jobs.append(("kernel_fq_d768", export_kernel_fq))

    for name, build in jobs:
        lowered, inputs, outputs = build()
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname, "inputs": inputs, "outputs": outputs,
        }
        print(f"  lowered {name}: {len(inputs)} inputs, "
              f"{len(outputs)} outputs, {len(text) // 1024} KiB")

    for vname, cfg in M.CONFIGS.items():
        manifest["models"][vname] = model_info(cfg)
    # head variants share topology with base; record n_out for reg
    manifest["models"]["base_reg"] = model_info(
        M.ModelConfig(**{**base.__dict__, "n_out": 1}))
    manifest["golden"]["fake_quant"] = golden_fake_quant()

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
