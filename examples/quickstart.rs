//! Quickstart: load a fine-tuned checkpoint, calibrate, quantize with
//! per-tensor W8A8 and with PEG (the paper's method), and compare scores.
//!
//! Run after `make build && target/release/repro finetune --tasks mnli`:
//!     cargo run --release --example quickstart

use anyhow::Result;

use std::collections::BTreeMap;
use tq::coordinator::experiments::{eval_config, load_ckpt, EvalConfig};
use tq::coordinator::Ctx;
use tq::model::qconfig::{assemble_act_tensors, QuantPolicy, SiteCfg};
use tq::quant::Granularity;

fn main() -> Result<()> {
    let ctx = Ctx::new("artifacts", "checkpoints", "results")?;
    let task = ctx.task("mnli")?;
    let params = load_ckpt(&ctx, &task)?;
    let info = ctx.model_info(&task)?;

    // FP32 reference
    let fp32_act = assemble_act_tensors(info, &QuantPolicy::fp32(), &BTreeMap::new())?;
    let fp32 = tq::coordinator::eval::evaluate(&ctx, &task, &params, &fp32_act)?;
    println!("FP32                 : {fp32:.2}");

    // naive per-tensor W8A8 (paper Table 1: collapses)
    let w8a8 = eval_config(&ctx, &task, &params,
                           &EvalConfig::new(QuantPolicy::uniform(8, 8)), 1)?;
    println!("W8A8 per-tensor PTQ  : {w8a8:.2}");

    // PEG with range-based permutation on the FFN sites (paper Table 5)
    let peg_cfg = SiteCfg {
        granularity: Granularity::PerEmbeddingGroup { k: 8, permute: true },
        ..Default::default()
    };
    let mut policy = QuantPolicy::uniform(8, 8);
    for fam in ["ln1_out", "ffn_out", "res2_sum"] {
        policy = policy.with_site_family(info, fam, peg_cfg.clone());
    }
    let peg = eval_config(&ctx, &task, &params, &EvalConfig::new(policy), 1)?;
    println!("W8A8 PEG-PTQ (K=8+P) : {peg:.2}");

    println!(
        "\nPEG recovers {:.0}% of the quantization gap",
        100.0 * (peg - w8a8) / (fp32 - w8a8).max(1e-9)
    );
    Ok(())
}
