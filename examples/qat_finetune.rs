//! Quantization-aware training (paper §4): start from a PTQ-initialised
//! state and train with STE fake-quant + LSQ learnable ranges through the
//! AOT QAT train-step executable.
//!
//!     cargo run --release --example qat_finetune [-- <task> <steps≈epochs>]

use anyhow::Result;

use tq::coordinator::calibrate::{calibrate, CalibCfg};
use tq::coordinator::experiments::load_ckpt;
use tq::coordinator::train::{qat, qat_deployed_params, QatCfg};
use tq::coordinator::Ctx;
use tq::model::qconfig::{assemble_act_tensors, QuantPolicy};

fn main() -> Result<()> {
    let task_name = std::env::args().nth(1).unwrap_or_else(|| "rte".into());
    let epochs: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(1);
    let ctx = Ctx::new("artifacts", "checkpoints", "results")?;
    let task = ctx.task(&task_name)?;
    let info = ctx.model_info(&task)?;
    let params = load_ckpt(&ctx, &task)?;

    // PTQ init (paper: "initialize all quantization parameters from PTQ")
    println!("calibrating PTQ init ...");
    let calib = calibrate(&ctx, &task, &params, &CalibCfg::default())?;
    let act = assemble_act_tensors(info, &QuantPolicy::uniform(8, 8), &calib.trackers)?;
    let w8a8 = tq::coordinator::eval::evaluate(&ctx, &task, &params, &act)?;
    println!("W8A8 PTQ before QAT: {w8a8:.2}");

    println!("running QAT ({epochs} epoch(s); compiling the QAT graph takes ~3 min) ...");
    let res = qat(&ctx, &task, &params, &act,
                  &QatCfg { epochs, ..Default::default() })?;
    println!("QAT losses: first {:.4}, last {:.4}",
             res.losses.first().unwrap(), res.losses.last().unwrap());

    let (qp, qact) = qat_deployed_params(info, &res, 8, 8)?;
    let score = tq::coordinator::eval::evaluate(&ctx, &task, &qp, &qact)?;
    println!("W8A8 QAT after {} steps: {score:.2}", res.losses.len());
    Ok(())
}
