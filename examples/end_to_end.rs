//! End-to-end validation driver (DESIGN.md): fine-tune the encoder on a
//! real (synthetic-GLUE) task through the AOT train-step executable,
//! logging the loss curve, then run the full PTQ pipeline — calibration,
//! range estimation, weight QDQ, PEG assembly — and report the paper's
//! headline comparison (FP32 vs W8A8 vs PEG-PTQ vs MP-PTQ).
//!
//!     cargo run --release --example end_to_end [-- <task> <epochs>]
//!
//! Proves all three layers compose: L1 Pallas kernels lowered into the L2
//! HLO graphs, executed by the L3 Rust coordinator via PJRT.

use anyhow::Result;

use std::collections::BTreeMap;
use tq::coordinator::experiments::{eval_config, EvalConfig};
use tq::coordinator::train::{finetune, TrainCfg};
use tq::coordinator::Ctx;
use tq::model::qconfig::{assemble_act_tensors, QuantPolicy, SiteCfg};
use tq::quant::Granularity;

fn main() -> Result<()> {
    let task_name = std::env::args().nth(1).unwrap_or_else(|| "sst2".into());
    let epochs: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(5);
    let ctx = Ctx::new("artifacts", "checkpoints", "results")?;
    let task = ctx.task(&task_name)?;
    let info = ctx.model_info(&task)?;

    // --- stage 1: fine-tune through the AOT train-step executable -------
    println!("== stage 1: FP32 fine-tuning ({epochs} epochs, batch 16) ==");
    let t0 = std::time::Instant::now();
    let res = finetune(&ctx, &task, &TrainCfg { epochs, ..Default::default() })?;
    println!(
        "trained {} steps in {:.0}s; loss {:.3} -> {:.3}",
        res.losses.len(),
        t0.elapsed().as_secs_f32(),
        res.losses[0],
        res.losses.last().unwrap()
    );
    // loss curve (every 16th step)
    for (i, l) in res.losses.iter().enumerate().step_by(res.losses.len() / 16) {
        println!("  step {i:>4}: loss {l:.4}");
    }

    // --- stage 2: the PTQ pipeline ---------------------------------------
    println!("\n== stage 2: post-training quantization pipeline ==");
    let fp32_act = assemble_act_tensors(info, &QuantPolicy::fp32(), &BTreeMap::new())?;
    let fp32 = tq::coordinator::eval::evaluate(&ctx, &task, &res.params, &fp32_act)?;
    let w8a8 = eval_config(&ctx, &task, &res.params,
                           &EvalConfig::new(QuantPolicy::uniform(8, 8)), 1)?;
    let peg_cfg = SiteCfg {
        granularity: Granularity::PerEmbeddingGroup { k: 8, permute: true },
        ..Default::default()
    };
    let mut peg_policy = QuantPolicy::uniform(8, 8);
    for fam in ["ln1_out", "ffn_out", "res2_sum"] {
        peg_policy = peg_policy.with_site_family(info, fam, peg_cfg.clone());
    }
    let peg = eval_config(&ctx, &task, &res.params, &EvalConfig::new(peg_policy), 1)?;
    let a16 = SiteCfg { bits: 16, ..Default::default() };
    let mp_policy = QuantPolicy::uniform(8, 8)
        .with_site_family(info, "res2_sum", a16.clone())
        .with_site_family(info, "ln1_out", a16.clone())
        .with_site_family(info, "ffn_out", a16);
    let mp = eval_config(&ctx, &task, &res.params, &EvalConfig::new(mp_policy), 1)?;

    println!("\n== headline comparison (task {task_name}, score x100) ==");
    println!("  FP32                  {fp32:.2}");
    println!("  W8A8 per-tensor PTQ   {w8a8:.2}");
    println!("  W8A8 PEG-PTQ (K=8+P)  {peg:.2}");
    println!("  W8A{{8,16}} MP-PTQ      {mp:.2}");

    let stats = ctx.rt.stats();
    println!(
        "\nruntime: {} executions, {:.1}s XLA exec, {:.1}s output fetch",
        stats.executions,
        stats.exec_nanos as f64 / 1e9,
        stats.output_fetch_nanos as f64 / 1e9
    );
    Ok(())
}
