//! Outlier analysis (paper §3 / Fig. 2, plus the "Quantizable
//! Transformers" follow-up): inspect the structured FFN-output outliers
//! of the vanilla fixture checkpoint, then profile the same activations
//! with the streaming outlier-statistics pass and compare against the
//! clipped-softmax / gated-attention variant models, which ship without
//! installed outliers.
//!
//!     cargo run --release --example outlier_analysis [-- <task>]

use anyhow::Result;

use tq::analysis::outlier_stats;
use tq::coordinator::diagnostics as diag;
use tq::coordinator::experiments::load_ckpt_var;
use tq::coordinator::Ctx;
use tq::model::manifest::{model_name, Architecture, AttnVariant};
use tq::report::{bar_chart, bool_heatmap};

fn main() -> Result<()> {
    let task_name = std::env::args().nth(1).unwrap_or_else(|| "mnli".into());
    let ctx = Ctx::new("artifacts", "checkpoints", "results")?;
    let task = ctx.task(&task_name)?;
    let arch = Architecture::Bert;

    // Part 1 — the classic Fig. 2 view of the vanilla checkpoint:
    // per-token dynamic ranges and the >6σ outlier map in the deepest
    // encoder layer.
    let params = load_ckpt_var(&ctx, &task, arch, AttnVariant::Vanilla)?;
    let info = ctx.model_info(&task)?;
    let layer = info.config.layers - 1;
    let (cls_id, sep_id) = (info.config.arch.cls_id(), info.config.arch.sep_id());

    let runs = diag::collect_taps(&ctx, &task, &params, 10)?;
    let ex = &runs.examples[0];

    for (name, site) in [("FFN input ", format!("layer{layer}.ln1_out")),
                         ("FFN output", format!("layer{layer}.ffn_out"))] {
        let t = &runs.per_seq[0][&site];
        println!("\n{name} (layer {layer}): tensor range [{:.2}, {:.2}]", t.min(), t.max());
        let (lo, hi) = diag::per_token_ranges(&runs.per_seq[0], &site, &ex.mask);
        let ranges: Vec<f32> = lo.iter().zip(&hi).map(|(l, h)| h - l).collect();
        let labels: Vec<String> = ex.ids.iter().take(ranges.len())
            .map(|&id| if Some(id) == sep_id { "[SEP]".into() }
                 else if Some(id) == cls_id { "[CLS]".into() }
                 else { format!("tok{id}") })
            .collect();
        println!("{}", bar_chart(&ranges, 40, Some(&labels)));
    }

    println!("\n>6σ outlier map, FFN output, sequence 0 (rows = tokens):");
    let (mask, rows, d) = diag::outlier_mask(&runs.per_seq[0], &format!("layer{layer}.ffn_out"));
    println!("{}", bool_heatmap(&mask, rows, d, 128));

    let dims = diag::consistent_outlier_dims(&runs, &format!("layer{layer}.ffn_out"), 6);
    println!("consistent outlier dims across 10 sequences: {dims:?}");
    println!("(installed in the checkpoint at dims {:?})", info.config.outlier_dims);

    // Part 2 — the streaming outlier-statistics pass (`repro diag
    // --outliers`): per-site ∞-norm / kurtosis / top-lane concentration,
    // vanilla vs the outlier-free attention variants.
    println!("\nper-family outlier profile ({task_name}, 10 seqs):");
    for variant in [AttnVariant::Vanilla, AttnVariant::ClippedSoftmax, AttnVariant::Gated] {
        let params = load_ckpt_var(&ctx, &task, arch, variant)?;
        let run = diag::collect_taps_var(&ctx, &task, arch, variant, &params, 10)?;
        let stats = outlier_stats(&run)?;
        let max_inf = stats.values().map(|s| s.inf_norm).fold(0.0f32, f32::max);
        let max_kurt = stats.values().map(|s| s.kurtosis).fold(0.0, f64::max);
        let (site, worst) = stats
            .iter()
            .max_by(|a, b| a.1.kurtosis.total_cmp(&b.1.kurtosis))
            .expect("tap sites");
        println!(
            "  {:<10} max inf-norm {:8.3}  max kurtosis {:8.2}  worst site {} \
             (lane {} carries {:.0}% of its energy)",
            model_name(arch, variant, false),
            max_inf,
            max_kurt,
            site,
            worst.top_lane,
            100.0 * worst.top_share
        );
    }
    println!(
        "\nvanilla >> variants: the clipped-softmax / gated-attention models \
         quantize to\nper-tensor W8A8 without PEG — sweep the axis with \
         `repro sweep --variants ...`."
    );
    Ok(())
}
