//! Outlier analysis (paper §3 / Fig. 2): inspect the FFN input/output
//! dynamic ranges and the structured outliers in the deepest encoder
//! layer of a fine-tuned checkpoint.
//!
//!     cargo run --release --example outlier_analysis [-- <task>]

use anyhow::Result;

use tq::coordinator::diagnostics as diag;
use tq::coordinator::experiments::load_ckpt;
use tq::coordinator::Ctx;
use tq::report::{bar_chart, bool_heatmap};

fn main() -> Result<()> {
    let task_name = std::env::args().nth(1).unwrap_or_else(|| "mnli".into());
    let ctx = Ctx::new("artifacts", "checkpoints", "results")?;
    let task = ctx.task(&task_name)?;
    let params = load_ckpt(&ctx, &task)?;
    let info = ctx.model_info(&task)?;
    let layer = info.config.layers - 1;

    let runs = diag::collect_taps(&ctx, &task, &params, 10)?;
    let ex = &runs.examples[0];

    for (name, site) in [("FFN input ", format!("layer{layer}.ln1_out")),
                         ("FFN output", format!("layer{layer}.ffn_out"))] {
        let t = &runs.per_seq[0][&site];
        println!("\n{name} (layer {layer}): tensor range [{:.2}, {:.2}]", t.min(), t.max());
        let (lo, hi) = diag::per_token_ranges(&runs.per_seq[0], &site, &ex.mask);
        let ranges: Vec<f32> = lo.iter().zip(&hi).map(|(l, h)| h - l).collect();
        let labels: Vec<String> = ex.ids.iter().take(ranges.len())
            .map(|&id| if id == info.config.sep_id { "[SEP]".into() }
                 else if id == info.config.cls_id { "[CLS]".into() }
                 else { format!("tok{id}") })
            .collect();
        println!("{}", bar_chart(&ranges, 40, Some(&labels)));
    }

    println!("\n>6σ outlier map, FFN output, sequence 0 (rows = tokens):");
    let (mask, rows, d) = diag::outlier_mask(&runs.per_seq[0], &format!("layer{layer}.ffn_out"));
    println!("{}", bool_heatmap(&mask, rows, d, 128));

    let dims = diag::consistent_outlier_dims(&runs, &format!("layer{layer}.ffn_out"), 6);
    println!("consistent outlier dims across 10 sequences: {dims:?}");
    println!("(installed by the aux loss at dims {:?})", info.config.outlier_dims);
    Ok(())
}
